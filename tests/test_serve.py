"""Serving engine: wave batching, EOS handling, determinism."""

import dataclasses

import numpy as np
import pytest

from repro.configs import smoke_config
from repro.launch.mesh import make_local_mesh
from repro.serve import Request, ServeEngine, init_serve_params
from repro.sharding import default_rules


@pytest.fixture(scope="module")
def engine():
    cfg = smoke_config("starcoder2-7b")
    cfg = dataclasses.replace(cfg, num_layers=2, remat=False)
    params, _ = init_serve_params(cfg, seed=0)
    return ServeEngine(cfg, make_local_mesh(1, 1), default_rules(), params,
                       max_batch=4)


def _prompt(rng, n):
    return rng.integers(1, 500, n).astype(np.int32)


def test_wave_batching(engine):
    rng = np.random.default_rng(0)
    for i in range(10):
        engine.submit(Request(i, _prompt(rng, 5 + i), max_new_tokens=6))
    comps = engine.run()
    assert sorted(c.uid for c in comps) == list(range(10))
    assert engine.pending() == 0
    for c in comps:
        assert len(c.tokens) <= 6
        assert np.isfinite(c.tokens).all()


def test_batching_invariance(engine):
    """A request's output must not depend on its batch-mates."""
    rng = np.random.default_rng(1)
    p = _prompt(rng, 8)
    engine.submit(Request(100, p, max_new_tokens=5))
    solo = engine.run()[0]
    engine.submit(Request(101, p, max_new_tokens=5))
    engine.submit(Request(102, _prompt(rng, 8), max_new_tokens=5))
    engine.submit(Request(103, _prompt(rng, 3), max_new_tokens=5))
    batched = {c.uid: c for c in engine.run()}
    assert np.array_equal(solo.tokens, batched[101].tokens)


def test_eos_stops_early(engine):
    rng = np.random.default_rng(2)
    p = _prompt(rng, 6)
    engine.submit(Request(200, p, max_new_tokens=16, eos_id=-1))
    full = engine.run()[0]
    eos = int(full.tokens[1])          # force EOS at the 2nd generated tok
    engine.submit(Request(201, p, max_new_tokens=16, eos_id=eos))
    cut = engine.run()[0]
    assert len(cut.tokens) <= len(full.tokens)
    assert cut.tokens[-1] == eos
