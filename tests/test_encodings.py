"""Encoding satellite tests that must run without hypothesis:
choose_encoding's sample-relative string-cardinality fix, and the
encoding x dtype x validity x row-count round-trip grid (including the
width-parameterized integer BITPACK and packed DICTP indices)."""

import numpy as np
import pytest

from repro.aformat import encodings, parquet
from repro.aformat.table import Column, Table

# ---------------------------------------------------------------------------
# choose_encoding: string cardinality compares against the SAMPLE size
# ---------------------------------------------------------------------------


def test_choose_encoding_high_cardinality_string_regression():
    """100k unique strings: the old heuristic compared the 4096-row
    sample's uniq count against len(values)//4 = 25000, so any column
    over ~16k rows dictionary-encoded regardless of true cardinality.
    High-cardinality strings must stay PLAIN."""
    n = 100_000
    vals = np.asarray([f"user-{i:07d}" for i in range(n)], object)
    assert encodings.choose_encoding("string", vals) == encodings.PLAIN
    # and DICT still wins when the sample really is low-cardinality
    low = np.asarray(["a", "b", "c", "d"] * (n // 4), object)
    assert encodings.choose_encoding("string", low) == encodings.DICT


# ---------------------------------------------------------------------------
# encodings round-trip grid: encoding x dtype x validity x 0/1-row edges
# ---------------------------------------------------------------------------


def _grid_values(ftype, n, rng):
    if ftype == "string":
        return np.asarray(rng.choice(["aa", "b", "cccc", "dd"], n)
                          if n else [], object)
    if ftype == "bool":
        return rng.integers(0, 2, n) == 0
    dt = np.dtype(ftype)
    return rng.integers(-50, 50, n).astype(dt) if dt.kind == "i" \
        else rng.normal(size=n).astype(dt)


_GRID = [
    ("plain", ["int32", "int64", "float32", "float64", "string", "bool"]),
    ("dict", ["int32", "int64", "float32", "float64", "string"]),
    ("dictp", ["int32", "int64", "float32", "float64", "string"]),
    ("rle", ["int32", "int64", "float32", "float64", "bool"]),
    ("delta", ["int32", "int64"]),
    ("bitpack", ["bool", "int32", "int64"]),
]


@pytest.mark.parametrize("enc,types", _GRID)
@pytest.mark.parametrize("n", [0, 1, 3, 257])
def test_encoding_grid_roundtrip(enc, types, n):
    rng = np.random.default_rng(7 * n + 1)
    for ftype in types:
        vals = _grid_values(ftype, n, rng)
        if enc == "delta":
            vals = np.sort(vals)
        try:
            bufs = encodings.encode(ftype, enc, vals)
        except ValueError:
            continue  # encoding legitimately refused for these values
        dt = None if ftype == "string" else np.dtype(ftype)
        back = encodings.decode(ftype, enc, bufs, n, dt)
        if ftype == "string":
            assert [str(v) for v in back] == [str(v) for v in vals]
        else:
            assert np.array_equal(np.asarray(back, dt), vals), \
                (enc, ftype, n)


@pytest.mark.parametrize("ftype", ["int32", "int64"])
def test_int_bitpack_width_parameterized(ftype):
    """Integer BITPACK rebases to min and packs at the range's width."""
    rng = np.random.default_rng(0)
    vals = (rng.integers(0, 6, 1000) + 1_000_000).astype(ftype)
    bufs = encodings.encode(ftype, encodings.BITPACK, vals)
    # header (base + width byte) and 3 bits/value of payload
    assert len(bufs[0]) == 9
    assert len(bufs[1]) == -(-1000 * 3 // 8)
    back = encodings.decode(ftype, encodings.BITPACK, bufs, 1000,
                            np.dtype(ftype))
    assert np.array_equal(back, vals)
    # negatives rebase too
    neg = np.asarray([-7, -3, -7, -1], ftype)
    bufs = encodings.encode(ftype, encodings.BITPACK, neg)
    back = encodings.decode(ftype, encodings.BITPACK, bufs, 4,
                            np.dtype(ftype))
    assert np.array_equal(back, neg)


def test_int_bitpack_overflow_refused():
    vals = np.asarray([-2**62, 2**62], np.int64)
    with pytest.raises(ValueError):
        encodings.encode("int64", encodings.BITPACK, vals)
    with pytest.raises(ValueError):
        encodings.encode("float64", encodings.BITPACK,
                         np.asarray([1.0, 2.0]))


def test_dictp_packs_indices():
    vals = np.asarray(["x", "y"] * 500, object)
    dict_bufs = encodings.encode("string", encodings.DICT, vals)
    packed = encodings.encode("string", encodings.DICTP, vals)
    # int32 codes: 4 bytes/row; packed: 1 bit/row (2 uniques) + width
    assert len(dict_bufs[0]) == 4000
    assert len(packed[0]) == 1 + 125
    back = encodings.decode("string", encodings.DICTP, packed, 1000, None)
    assert [str(v) for v in back] == [str(v) for v in vals]


def test_grid_roundtrip_with_validity_through_file():
    """Validity rides as the trailing buffer for every encoding: pin it
    end-to-end through write_table/scan_file (nulls must survive the
    advisor's re-encode too)."""
    n = 500
    rng = np.random.default_rng(3)
    validity = rng.integers(0, 5, n) > 0
    from repro.aformat.schema import schema

    sch = schema(("a", "int64"), ("b", "string"), nullable=("a",))
    cols = [Column(sch.field("a"),
                   rng.integers(0, 4, n).astype(np.int64), validity),
            Column(sch.field("b"),
                   np.asarray(rng.choice(["p", "q"], n), object))]
    t = Table(sch, cols)
    for advise in (False, True):
        data = parquet.write_table(t, row_group_rows=200, advise=advise)
        out = parquet.scan_file(parquet.BytesSource(data))
        col = out.column("a")
        assert np.array_equal(col.validity, validity)
        assert np.array_equal(col.values[validity],
                              cols[0].values[validity])
