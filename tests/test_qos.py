"""Multi-tenant QoS: weighted-fair admission, priority lanes, deadline
shedding, per-tenant caching/metrics, and the TaskContext compat shim.

The grant policy itself (weighted fairness, lane priority, preemption)
is pinned deterministically against ``_OsdSlots`` — single-threaded
waiter-queue manipulation, no timing — and then the integrated stack
(registry -> query -> shared controller -> typed ``Shed``) is exercised
end to end, including the regression grid proving the default tenant
reproduces the historic single-tenant behavior at every layout x format
point.
"""

import threading
import time
import warnings

import numpy as np
import pytest

from repro.aformat.expressions import field
from repro.core import (ParquetFormat, Shed, TaskContext, TenantRegistry,
                        dataset, make_cluster, write_flat, write_split,
                        write_striped)
from repro.dataset import MutableDataset, ResultCache
from repro.dataset.admission import (LANE_PRIORITY, AdmissionController,
                                     AdmissionTimeout, _OsdSlots, _Waiter)
from repro.dataset.qos import resolve_context


@pytest.fixture
def flat_ds(taxi_table):
    fs = make_cluster(8)
    for i in range(4):
        write_flat(fs, f"/d/part{i}.arw", taxi_table.slice(i * 5000, 5000),
                   row_group_rows=1024)
    return fs, dataset(fs, "/d"), taxi_table


def _enqueue(slots: _OsdSlots, tenant: str, lane: str,
             weight: float) -> _Waiter:
    slots._seq += 1
    w = _Waiter(tenant, LANE_PRIORITY[lane], weight, slots._seq)
    slots.waiters.append(w)
    return w


def _drain_one(slots: _OsdSlots, holder: str, pending: list) -> _Waiter:
    """Release ``holder``'s slot; return the waiter the policy granted."""
    slots.release(holder)
    w = next(x for x in pending if x.granted)
    pending.remove(w)
    return w


# ---------------------------------------------------------------------------
# Grant policy (deterministic: no threads, no clocks)
# ---------------------------------------------------------------------------


def test_weighted_fair_split_converges_to_weights():
    """Under saturation the slot split converges to registered weights:
    tenant a (weight 3) takes exactly 3x the grants of b (weight 1)."""
    slots = _OsdSlots(slots=4, slack=0)
    held = ["a", "a", "a", "b"]          # seed at the fair split
    for t in held:
        slots._take(t)
    pending = [_enqueue(slots, "a", "bulk", 3.0),
               _enqueue(slots, "b", "bulk", 1.0)]
    grants = {"a": 0, "b": 0}
    for _ in range(200):
        w = _drain_one(slots, held.pop(0), pending)
        grants[w.tenant] += 1
        held.append(w.tenant)
        pending.append(_enqueue(slots, w.tenant, "bulk", w.weight))
    assert grants == {"a": 150, "b": 50}


def test_equal_weights_split_evenly():
    slots = _OsdSlots(slots=2, slack=0)
    held = ["a", "b"]
    for t in held:
        slots._take(t)
    pending = [_enqueue(slots, "a", "bulk", 1.0),
               _enqueue(slots, "b", "bulk", 1.0)]
    grants = {"a": 0, "b": 0}
    for _ in range(100):
        w = _drain_one(slots, held.pop(0), pending)
        grants[w.tenant] += 1
        held.append(w.tenant)
        pending.append(_enqueue(slots, w.tenant, "bulk", 1.0))
    assert grants == {"a": 50, "b": 50}


def test_lane_priority_orders_grants():
    """A freed slot never goes to a lane while a higher lane waits —
    and no weight can trump a lane."""
    slots = _OsdSlots(slots=1, slack=0)
    slots._take("warm")
    pending = [_enqueue(slots, "maint", "background", 100.0),
               _enqueue(slots, "etl", "bulk", 1.0),
               _enqueue(slots, "app", "interactive", 1.0)]
    order = []
    holder = "warm"
    for _ in range(3):
        w = _drain_one(slots, holder, pending)
        order.append(w.tenant)
        holder = w.tenant
    assert order == ["app", "etl", "maint"]


def test_compaction_waits_behind_interactive_grant():
    """The compaction lane never starves a foreground scan: with both a
    background and an interactive waiter queued, the freed slot always
    goes to the interactive waiter first."""
    slots = _OsdSlots(slots=1, slack=0)
    slots._take("warm")
    pending = [_enqueue(slots, "compaction", "background", 1.0),
               _enqueue(slots, "app", "interactive", 1.0)]
    assert _drain_one(slots, "warm", pending).tenant == "app"
    assert _drain_one(slots, "app", pending).tenant == "compaction"


def test_interactive_preempts_full_node():
    """An interactive arrival on a saturated node oversubscribes into the
    preempt slack instead of queueing behind bulk work."""
    slots = _OsdSlots(slots=1, slack=1)
    slots._take("etl")                      # node full
    _enqueue(slots, "etl", "bulk", 1.0)     # and a bulk waiter queued
    waited, preempted, wait_s = slots.acquire(
        "app", LANE_PRIORITY["interactive"], 1.0, lambda: None)
    assert (waited, preempted, wait_s) == (False, True, 0.0)
    assert slots.inflight == 2              # oversubscribed by the slack
    assert not slots.waiters[0].granted     # bulk still waits


def test_interactive_queues_behind_interactive():
    """Preemption slack is for jumping *lower* lanes only: a second
    interactive arrival queues FIFO behind the first."""
    slots = _OsdSlots(slots=1, slack=1)
    slots._take("app")
    _enqueue(slots, "app2", "interactive", 1.0)
    result = {}

    def acquire():
        result["r"] = slots.acquire(
            "app3", LANE_PRIORITY["interactive"], 1.0, lambda: None)

    t = threading.Thread(target=acquire)
    t.start()
    for _ in range(500):
        if len(slots.waiters) == 2:
            break
        time.sleep(0.002)
    assert len(slots.waiters) == 2          # app3 queued, no slack jump
    slots.release("app")                    # grants app2 (FIFO), not app3
    assert slots.by_tenant.get("app2") == 1
    slots.release("app2")
    t.join(5)
    waited, preempted, _ = result["r"]
    assert waited and not preempted


def test_controller_counts_preemptions():
    fs = make_cluster(2)
    ctrl = AdmissionController(fs.store, slots_per_osd=1, preempt_slack=1)
    app = TaskContext(tenant="app", lane="interactive")
    with ctrl.admit(0):                     # default bulk holds the slot
        with ctrl.admit(0, app):            # jumps in, does not block
            pass
    st = ctrl.stats()
    assert st["preemptions"] == 1
    assert st["by_tenant"]["app"]["preemptions"] == 1
    assert st["by_tenant"]["default"]["preemptions"] == 0


def test_admission_controller_records_wait_time():
    """The bugfix: ``wait_s`` (queue *time*) is recorded per acquisition,
    not just the blocked-or-not ``waits`` counter."""
    fs = make_cluster(2)
    ctrl = AdmissionController(fs.store, slots_per_osd=1)
    entered = threading.Event()
    release = threading.Event()

    def hold():
        with ctrl.admit(0):
            entered.set()
            release.wait(5)

    t = threading.Thread(target=hold)
    t.start()
    assert entered.wait(5)
    threading.Timer(0.05, release.set).start()
    with ctrl.admit(0):
        pass
    t.join(5)
    st = ctrl.stats()
    assert st["admitted"] == 2
    assert st["waits"] == 1
    assert st["wait_s"] >= 0.04             # actually measured queue time
    assert st["by_tenant"]["default"]["wait_s"] == \
        pytest.approx(st["wait_s"], abs=1e-5)


def test_deadline_expiry_in_queue_raises_admission_timeout():
    fs = make_cluster(2)
    ctrl = AdmissionController(fs.store, slots_per_osd=1)
    entered = threading.Event()
    release = threading.Event()

    def hold():
        with ctrl.admit(0):
            entered.set()
            release.wait(5)

    t = threading.Thread(target=hold)
    t.start()
    assert entered.wait(5)
    ctx = TaskContext(tenant="late", deadline_s=0.03,
                      started_at=time.perf_counter())
    try:
        with pytest.raises(AdmissionTimeout):
            with ctrl.admit(0, ctx):
                pass
    finally:
        release.set()
        t.join(5)
    st = ctrl.stats()
    assert st["sheds"] == 1
    assert st["by_tenant"]["late"]["sheds"] == 1
    assert st["by_tenant"]["late"]["wait_s"] >= 0.02
    assert st["by_tenant"]["late"]["admitted"] == 0


# ---------------------------------------------------------------------------
# Registry + query integration
# ---------------------------------------------------------------------------


def test_tenant_tagged_query_and_rollup(flat_ds):
    fs, ds, tbl = flat_ds
    reg = TenantRegistry()
    reg.register("app", weight=4.0, lane="interactive")
    reg.register("etl", weight=1.0, lane="bulk")

    qa = ds.query(tenant=reg.context("app")).filter(
        field("fare_amount") > 30.0)
    out = qa.to_table()
    expect = int((tbl.column("fare_amount").values > 30.0).sum())
    assert len(out) == expect
    assert qa.metrics.tenant == "app"
    assert qa.metrics.lane == "interactive"
    s = qa.metrics.summary()
    assert s["tenant"] == "app" and s["lane"] == "interactive"
    for k in ("admission_wait_s", "preemptions", "sheds"):
        assert k in s

    # filtered: a bare COUNT(*) is metadata-answered, no storage work
    qe = ds.query(tenant=reg.context("etl")).filter(
        field("fare_amount") > 30.0).count()
    assert qe.to_scalar() == expect
    assert qe.metrics.tenant == "etl" and qe.metrics.lane == "bulk"

    by = reg.by_tenant()
    assert by["app"]["runs"] == 1 and by["etl"]["runs"] == 1
    assert by["app"]["rows"] == expect
    assert by["app"]["admission"]["admitted"] == len(qa.metrics.tasks)
    assert by["etl"]["admission"]["admitted"] == len(qe.metrics.tasks)


def test_scan_metrics_surface_wait_time(flat_ds):
    fs, ds, _ = flat_ds
    sc = ds.scanner(format="pushdown", columns=["trip_id"],
                    num_threads=16, queue_depth=1)
    sc.to_table()
    adm = sc.metrics.admission
    assert adm["admitted"] == len(sc.metrics.tasks)
    assert "wait_s" in adm and "preemptions" in adm and "sheds" in adm
    if adm["waits"]:
        assert adm["wait_s"] > 0.0


def test_explain_shows_tenant_lane_deadline(flat_ds):
    _, ds, _ = flat_ds
    reg = TenantRegistry()
    reg.register("app", lane="interactive")
    txt = ds.query(tenant=reg.context("app", deadline_s=0.5)).explain()
    assert "tenant=app/interactive" in txt
    assert "deadline=500ms/reject" in txt
    # the default tenant keeps the historic executor line
    assert "tenant=" not in ds.query().explain()


def test_deadline_shed_is_typed_and_deterministic(flat_ds):
    """An impossible deadline under injected straggle sheds every time —
    as a typed Shed result, never an exception from a worker thread."""
    fs, ds, _ = flat_ds
    for osd in fs.store.osds:
        osd.straggle_factor = 40.0          # every storage call is slow
    reg = TenantRegistry()
    reg.register("app", lane="interactive", deadline_s=1e-4)
    for _ in range(3):
        q = ds.query(tenant=reg.context("app"), num_threads=1)
        out = q.to_table()
        assert isinstance(out, Shed)
        assert out.tenant == "app" and out.lane == "interactive"
        assert out.completed_tasks < out.total_tasks
        assert out.partial is None          # reject policy
        assert q.metrics.shed is out
        assert "shed" in q.metrics.summary()
    assert reg.by_tenant()["app"]["sheds"] == 3


def test_shed_retry_is_byte_identical(flat_ds):
    """A shed query retried without the deadline returns exactly the
    bytes a never-shed control run returns."""
    fs, ds, _ = flat_ds
    reg = TenantRegistry()
    reg.register("app", lane="interactive")
    pred = field("passenger_count") > 3
    control = (ds.query(tenant=reg.context("app")).filter(pred)
               .to_table())
    shed = (ds.query(tenant=reg.context("app", deadline_s=1e-9),
                     num_threads=1).filter(pred).to_table())
    assert isinstance(shed, Shed)
    retry = (ds.query(tenant=reg.context("app")).filter(pred)
             .to_table())
    assert retry.to_ipc() == control.to_ipc()


def test_degrade_policy_attaches_partial(flat_ds):
    fs, ds, _ = flat_ds
    for osd in fs.store.osds:
        osd.straggle_factor = 40.0
    reg = TenantRegistry()
    reg.register("dash", lane="interactive", deadline_s=0.1,
                 shed_policy="degrade")
    q = ds.query(tenant=reg.context("dash"), num_threads=1).select(
        "trip_id")
    out = q.to_table()
    assert isinstance(out, Shed)
    assert out.partial is not None
    assert len(out.partial) == sum(t.rows_out for t in q.metrics.tasks)
    assert out.completed_tasks < out.total_tasks


def test_scalar_shed_has_no_partial(flat_ds):
    """Aggregates never degrade: a partial aggregate is a wrong answer."""
    fs, ds, _ = flat_ds
    for osd in fs.store.osds:
        osd.straggle_factor = 40.0
    reg = TenantRegistry()
    reg.register("dash", lane="interactive", deadline_s=1e-4,
                 shed_policy="degrade")
    # filtered so the count needs storage tasks (not metadata-answered)
    out = (ds.query(tenant=reg.context("dash"), num_threads=1)
           .filter(field("fare_amount") > 30.0).count().to_scalar())
    assert isinstance(out, Shed)
    assert out.partial is None


def test_compaction_runs_as_background_tenant(taxi_table):
    """compact() rides the background lane through the registry's shared
    controller, and foreground scans against the same cluster complete
    correctly afterwards."""
    fs = make_cluster(4)
    md = MutableDataset.create(fs, "/mut")
    for i in range(6):
        md.append(taxi_table.slice(i * 1000, 1000))
    reg = TenantRegistry(slots_per_osd=2)
    reg.register("app", weight=4.0, lane="interactive")
    reg.register("compaction", lane="background")

    report = md.compact(tenant=reg.context("compaction"))
    assert report.groups > 0
    by = reg.by_tenant()
    assert by["compaction"]["admission"]["admitted"] >= 1

    out = md.query(tenant=reg.context("app")).to_table()
    assert len(out) == 6000
    assert reg.by_tenant()["app"]["runs"] == 1


# ---------------------------------------------------------------------------
# Per-tenant result cache
# ---------------------------------------------------------------------------


def test_cache_bulk_cannot_evict_interactive_working_set():
    cache = ResultCache(capacity_bytes=4096)
    cache.put(("hot", 1), b"x" * 512, tenant="app", budget=1024)
    for i in range(64):
        cache.put(("cold", i), b"y" * 1024, tenant="etl", budget=2048)
    assert cache.contains(("hot", 1), tenant="app")
    by = cache.by_tenant()
    assert by["etl"]["bytes"] <= 2048
    assert by["app"]["bytes"] == 512


def test_cache_budget_bounds_own_shard_lru():
    cache = ResultCache(capacity_bytes=1 << 20)
    for i in range(10):
        cache.put(("k", i), b"z" * 100, tenant="t", budget=350)
    assert cache.by_tenant()["t"]["bytes"] <= 350
    # LRU within the shard: the newest entries survive
    assert cache.contains(("k", 9), tenant="t")
    assert not cache.contains(("k", 0), tenant="t")


def test_cache_default_tenant_matches_historic_behavior():
    cache = ResultCache(capacity_bytes=1000)
    for i in range(5):
        cache.put(("k", i), b"a" * 300)
    assert len(cache) == 3                  # 900 bytes fit; LRU evicted 2
    assert cache.get(("k", 4)) == b"a" * 300
    assert cache.get(("k", 0)) is None
    st = cache.stats()
    assert st["evictions"] == 2
    assert set(st) == {"entries", "bytes", "hits", "misses", "evictions"}


def test_cache_entries_are_tenant_scoped():
    cache = ResultCache()
    cache.put(("k",), b"v", tenant="a")
    assert cache.get(("k",), tenant="b") is None
    assert cache.get(("k",), tenant="a") == b"v"
    assert cache.contains(("k",))           # any-tenant probe


# ---------------------------------------------------------------------------
# TaskContext compat shim
# ---------------------------------------------------------------------------


def test_legacy_kwarg_tail_warns_and_adapts(flat_ds):
    fs, ds, _ = flat_ds
    fmt = ParquetFormat()
    frag = ds.fragments()[0]
    ctrl = AdmissionController(fs.store)
    with pytest.warns(DeprecationWarning):
        tbl, _ = fmt.scan_fragment(fs, frag, ["trip_id"], None,
                                   admission=ctrl)
    assert len(tbl) == frag.num_rows
    assert ctrl.stats()["admitted"] == 1
    with pytest.warns(DeprecationWarning):
        tbl2, _ = fmt.scan_fragment(fs, frag, ["trip_id"], None, limit=7)
    assert len(tbl2) == 7


def test_legacy_positional_admission_warns(flat_ds):
    fs, ds, _ = flat_ds
    fmt = ParquetFormat()
    frag = ds.fragments()[0]
    ctrl = AdmissionController(fs.store)
    with pytest.warns(DeprecationWarning):
        tbl, _ = fmt.scan_fragment(fs, frag, ["trip_id"], None, ctrl)
    assert len(tbl) == frag.num_rows
    assert ctrl.stats()["admitted"] == 1


def test_legacy_override_subclass_still_executes(flat_ds):
    """A format subclass written before TaskContext (old kwarg-tail
    signature) keeps working through the executor, with one warning."""
    fs, ds, tbl = flat_ds

    class OldStyleFormat(ParquetFormat):
        calls = 0

        def scan_fragment(self, fs, frag, columns, predicate,
                          admission=None, limit=None):
            OldStyleFormat.calls += 1
            return ParquetFormat.scan_fragment(
                self, fs, frag, columns, predicate,
                TaskContext(admission=admission, limit=limit))

    with warnings.catch_warnings(record=True) as caught:
        warnings.simplefilter("always")
        out = ds.query(format=OldStyleFormat()).select("trip_id").to_table()
    assert len(out) == len(tbl)
    assert OldStyleFormat.calls == len(ds.fragments())
    assert any(issubclass(w.category, DeprecationWarning) for w in caught)


def test_resolve_context_rejects_unknown_kwargs():
    with pytest.raises(TypeError):
        resolve_context(None, {"bogus": 1})
    with pytest.raises(TypeError):
        resolve_context(object())


# ---------------------------------------------------------------------------
# Single-tenant regression grid: default tenant == historic behavior
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["flat", "split", "striped"])
@pytest.mark.parametrize("fmt", ["parquet", "pushdown", "adaptive"])
def test_single_tenant_grid_unchanged(taxi_table, layout, fmt):
    fs = make_cluster(8)
    writer = {"flat": write_flat, "split": write_split,
              "striped": write_striped}[layout]
    sub = taxi_table.slice(0, 4000)
    writer(fs, "/g/part0.arw", sub, row_group_rows=1000)
    ds = dataset(fs, "/g")
    pred = field("fare_amount") > 25.0
    q = ds.query(format=fmt).filter(pred).select("trip_id")
    out = q.to_table()
    expect = sub.column("trip_id").values[
        sub.column("fare_amount").values > 25.0]
    assert np.array_equal(np.sort(out.column("trip_id").values),
                          np.sort(expect))
    assert q.metrics.tenant == "default"
    assert q.metrics.shed is None
    n = ds.query(format=fmt).filter(pred).count().to_scalar()
    assert n == len(expect)
