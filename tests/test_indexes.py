"""Physical-design indexes: bloom blocks, FPR bounds, back-compat,
and the pruning-soundness differential.

The load-bearing invariants:

- a bloom verdict never drops a matching row (NONE is provable) — the
  differential scans with and without index blocks and compares bytes;
- footers written before index blocks existed load and scan unchanged
  (pinned against a serialized pre-change ARW1 file, generated with the
  unmodified writer before this subsystem landed);
- unknown index-block versions are skipped, not misread.
"""

import base64

import numpy as np
import pytest

from repro.aformat import parquet
from repro.aformat.expressions import (NONE, SOME, BloomIn, IsIn, field)
from repro.aformat.indexes import ColumnIndex, canonical_words, value_kind
from repro.aformat.table import Table
from repro.core import dataset, make_cluster, write_flat, write_split, \
    write_striped

WRITERS = {"flat": write_flat, "striped": write_striped,
           "split": write_split}


def _col(values, ftype, validity=None):
    from repro.aformat.schema import schema

    sch = schema(("x", ftype))
    return Table(sch, [parquet.Column(sch.field("x"),
                                      np.asarray(values), validity)]
                 ).column("x")


def _table(n=4000, seed=0):
    rng = np.random.default_rng(seed)
    return Table.from_pydict({
        "id": rng.permutation(np.arange(n, dtype=np.int64) * 13),
        "val": rng.normal(size=n).astype(np.float64),
        "tag": np.asarray([f"u{i:06d}" for i in range(n)], object),
    })


# ---------------------------------------------------------------------------
# ColumnIndex unit behavior
# ---------------------------------------------------------------------------


def test_build_counts_distinct_exactly():
    col = _col(np.asarray([5, 5, 7, 7, 7, 9], np.int64), "int64")
    idx = ColumnIndex.build(col)
    assert idx.kind == "i"
    assert idx.distinct == 3 and idx.count == 6


def test_build_skips_nulls():
    validity = np.asarray([True, False, True, False], "?")
    col = _col(np.asarray([1, 2, 3, 4], np.int64), "int64", validity)
    idx = ColumnIndex.build(col)
    assert idx.count == 2 and idx.distinct == 2
    assert idx.contains_any([1]) is True
    # 2 was masked out: the bloom may not claim it present
    assert idx.contains_any([2]) in (False, True)  # sound either way


def test_no_false_negatives_all_kinds():
    cases = [
        ("int64", np.arange(500, dtype=np.int64) * 7 - 1000),
        ("float64", np.linspace(-5.0, 5.0, 500)),
        ("string", np.asarray([f"key-{i}" for i in range(500)], object)),
    ]
    for ftype, vals in cases:
        idx = ColumnIndex.build(_col(vals, ftype))
        for v in vals[::37]:
            assert idx.contains_any([v]) is True, (ftype, v)


def test_fpr_bound():
    """At 8 bits/distinct with k=~5 hashes the theoretical FPR is ~2%;
    assert a generous 8% over a large absent-probe sample."""
    n = 4096
    idx = ColumnIndex.build(
        _col(np.arange(n, dtype=np.int64), "int64"))
    probes = np.arange(10_000, dtype=np.int64) * 3 + 1_000_000
    words = canonical_words("i", probes)
    hits = int(idx._probe_words(words).sum())
    assert hits / len(probes) < 0.08, hits


def test_probe_canonicalization_int_float():
    idx = ColumnIndex.build(_col(np.asarray([3, 8], np.int64), "int64"))
    # float probe 3.0 canonicalizes to int 3 -> present
    assert idx.contains_any([3.0]) is True
    # non-integral float can never equal an int value: no verdict abuse
    assert idx.contains_any([3.5]) is None
    assert canonical_words("i", ["not-an-int"]) is None


def test_value_kind_mapping():
    assert value_kind("int64") == value_kind("bool") == "i"
    assert value_kind("float32") == "f"
    assert value_kind("string") == "s"


# ---------------------------------------------------------------------------
# serialization: versioned block, unknown versions, pre-change footers
# ---------------------------------------------------------------------------


def test_index_json_roundtrip():
    idx = ColumnIndex.build(
        _col(np.arange(100, dtype=np.int64), "int64"))
    back = ColumnIndex.from_json(idx.to_json())
    assert back == idx


def test_unknown_version_skipped():
    idx = ColumnIndex.build(_col(np.arange(4, dtype=np.int64), "int64"))
    d = idx.to_json()
    d["v"] = 99
    assert ColumnIndex.from_json(d) is None
    assert ColumnIndex.from_json(None) is None
    assert ColumnIndex.from_json({}) is None


def test_footer_roundtrip_with_and_without_indexes():
    t = _table(600)
    data = parquet.write_table(t, row_group_rows=200)
    meta = parquet.read_footer(parquet.BytesSource(data))
    full = parquet.FileMeta.deserialize(meta.serialize())
    assert all(c.index is not None
               for rg in full.row_groups for c in rg.chunks)
    lean = parquet.FileMeta.deserialize(
        meta.serialize(include_indexes=False))
    assert all(c.index is None
               for rg in lean.row_groups for c in rg.chunks)
    # stripping indexes must not change any stats-visible field
    assert lean.num_rows == full.num_rows
    for a, b in zip(full.row_groups, lean.row_groups):
        assert [c.stats.min for c in a.chunks] == \
            [c.stats.min for c in b.chunks]


_GOLDEN_PRECHANGE_B64 = (
    "QVJXMXgBY2CAAAAACAABeAFjZEQBAACHABB4AWNgQAYP7CG8D1D6B5RmcICIs0BpDijNA6UF"
    "oLQQlBaB0mJQWgJKS0FpGSgt5wAAFNMHVngBY2BgYGAEYiYgZgZiECCFDwADIAAZeAFjYIAA"
    "RijNBKWZoTQLlAYAAMgAC3gBS0xKTgEAA9gBi3gBE2CAAAAAiAAReAFjZEQBAACHABB4AS3F"
    "xw0AIAwAsYxCDR1WyP5TIdD5Y5HH2U88B46cOLNy4cqNOw+evHjzsQuC/wZ5eAFjYGBgYARi"
    "JiBmBmIQIIUPAAMgABl4AWNggABGKM0EpZmhNAuUBgAAyAALeAFLTEpOAQAD2AGLeAFTYIAA"
    "AAEIACF4AWNkBAMAACMACHgBY2AAAQMHMNUApRkMoXwozWAE5UNpBmMo39gBAJ4IBY14AWNg"
    "gABGKM0EpZmhNAuUZoXSbFCaHUpzQGkABAgAJXgBS0xKTkkEYgAN2AMVeyJzY2hlbWEiOiB7"
    "ImZpZWxkcyI6IFt7Im5hbWUiOiAiaWQiLCAidHlwZSI6ICJpbnQ2NCIsICJudWxsYWJsZSI6"
    "IGZhbHNlfSwgeyJuYW1lIjogInZhbCIsICJ0eXBlIjogImZsb2F0NjQiLCAibnVsbGFibGUi"
    "OiBmYWxzZX0sIHsibmFtZSI6ICJ0YWciLCAidHlwZSI6ICJzdHJpbmciLCAibnVsbGFibGUi"
    "OiBmYWxzZX1dfSwgInJvd19ncm91cHMiOiBbeyJudW1fcm93cyI6IDE2LCAib2Zmc2V0Ijog"
    "NCwgInRvdGFsX2J5dGVzIjogMTMyLCAiY2h1bmtzIjogW3sib2Zmc2V0IjogNCwgImJ1ZmZl"
    "cl9sZW5ndGhzIjogWzExLCAxMV0sICJlbmNvZGluZyI6ICJkZWx0YSIsICJjb2RlYyI6ICJ6"
    "bGliIiwgInN0YXRzIjogeyJtaW4iOiAwLCAibWF4IjogMTUsICJudWxsX2NvdW50IjogMCwg"
    "ImNvdW50IjogMTZ9fSwgeyJvZmZzZXQiOiAyNiwgImJ1ZmZlcl9sZW5ndGhzIjogWzUzXSwg"
    "ImVuY29kaW5nIjogInBsYWluIiwgImNvZGVjIjogInpsaWIiLCAic3RhdHMiOiB7Im1pbiI6"
    "IDAuMCwgIm1heCI6IDcuNSwgIm51bGxfY291bnQiOiAwLCAiY291bnQiOiAxNn19LCB7Im9m"
    "ZnNldCI6IDc5LCAiYnVmZmVyX2xlbmd0aHMiOiBbMjMsIDIyLCAxMl0sICJlbmNvZGluZyI6"
    "ICJkaWN0IiwgImNvZGVjIjogInpsaWIiLCAic3RhdHMiOiB7Im1pbiI6ICJhIiwgIm1heCI6"
    "ICJkIiwgIm51bGxfY291bnQiOiAwLCAiY291bnQiOiAxNn19XX0sIHsibnVtX3Jvd3MiOiAx"
    "NiwgIm9mZnNldCI6IDEzNiwgInRvdGFsX2J5dGVzIjogMTI4LCAiY2h1bmtzIjogW3sib2Zm"
    "c2V0IjogMTM2LCAiYnVmZmVyX2xlbmd0aHMiOiBbMTEsIDExXSwgImVuY29kaW5nIjogImRl"
    "bHRhIiwgImNvZGVjIjogInpsaWIiLCAic3RhdHMiOiB7Im1pbiI6IDE2LCAibWF4IjogMzEs"
    "ICJudWxsX2NvdW50IjogMCwgImNvdW50IjogMTZ9fSwgeyJvZmZzZXQiOiAxNTgsICJidWZm"
    "ZXJfbGVuZ3RocyI6IFs0OV0sICJlbmNvZGluZyI6ICJwbGFpbiIsICJjb2RlYyI6ICJ6bGli"
    "IiwgInN0YXRzIjogeyJtaW4iOiA4LjAsICJtYXgiOiAxNS41LCAibnVsbF9jb3VudCI6IDAs"
    "ICJjb3VudCI6IDE2fX0sIHsib2Zmc2V0IjogMjA3LCAiYnVmZmVyX2xlbmd0aHMiOiBbMjMs"
    "IDIyLCAxMl0sICJlbmNvZGluZyI6ICJkaWN0IiwgImNvZGVjIjogInpsaWIiLCAic3RhdHMi"
    "OiB7Im1pbiI6ICJhIiwgIm1heCI6ICJkIiwgIm51bGxfY291bnQiOiAwLCAiY291bnQiOiAx"
    "Nn19XX0sIHsibnVtX3Jvd3MiOiA4LCAib2Zmc2V0IjogMjY0LCAidG90YWxfYnl0ZXMiOiAx"
    "MDIsICJjaHVua3MiOiBbeyJvZmZzZXQiOiAyNjQsICJidWZmZXJfbGVuZ3RocyI6IFsxMSwg"
    "MTFdLCAiZW5jb2RpbmciOiAiZGVsdGEiLCAiY29kZWMiOiAiemxpYiIsICJzdGF0cyI6IHsi"
    "bWluIjogMzIsICJtYXgiOiAzOSwgIm51bGxfY291bnQiOiAwLCAiY291bnQiOiA4fX0sIHsi"
    "b2Zmc2V0IjogMjg2LCAiYnVmZmVyX2xlbmd0aHMiOiBbMzRdLCAiZW5jb2RpbmciOiAicGxh"
    "aW4iLCAiY29kZWMiOiAiemxpYiIsICJzdGF0cyI6IHsibWluIjogMTYuMCwgIm1heCI6IDE5"
    "LjUsICJudWxsX2NvdW50IjogMCwgImNvdW50IjogOH19LCB7Im9mZnNldCI6IDMyMCwgImJ1"
    "ZmZlcl9sZW5ndGhzIjogWzMyLCAxNF0sICJlbmNvZGluZyI6ICJwbGFpbiIsICJjb2RlYyI6"
    "ICJ6bGliIiwgInN0YXRzIjogeyJtaW4iOiAiYSIsICJtYXgiOiAiZCIsICJudWxsX2NvdW50"
    "IjogMCwgImNvdW50IjogOH19XX1dLCAibnVtX3Jvd3MiOiA0MCwgImNyZWF0ZWRfYnkiOiAi"
    "cmVwcm8tYXJ3MSJ92AYAAEFSVzE="
)


def test_prechange_footer_loads_and_scans():
    """A file serialized by the writer BEFORE index blocks existed must
    load and scan byte-identically (backward compatibility)."""
    data = base64.b64decode(_GOLDEN_PRECHANGE_B64)
    src = parquet.BytesSource(data)
    meta = parquet.read_footer(src)
    assert meta.num_rows == 40 and len(meta.row_groups) == 3
    assert all(c.index is None
               for rg in meta.row_groups for c in rg.chunks)
    out = parquet.scan_file(src, predicate=(field("id") == 7))
    assert len(out) == 1
    assert out.column("val").values[0] == 3.5
    assert out.column("tag").values[0] == "d"
    # a no-index footer round-trips without growing an index field
    again = parquet.FileMeta.deserialize(meta.serialize())
    assert all(c.index is None
               for rg in again.row_groups for c in rg.chunks)


def test_write_table_build_indexes_off():
    t = _table(300)
    data = parquet.write_table(t, row_group_rows=100,
                               build_indexes=False)
    meta = parquet.read_footer(parquet.BytesSource(data))
    assert all(c.index is None
               for rg in meta.row_groups for c in rg.chunks)


# ---------------------------------------------------------------------------
# pruning: index verdicts at every choke point
# ---------------------------------------------------------------------------


def test_eq_isin_bloom_prune_upgrade():
    t = _table(2000)
    data = parquet.write_table(t, row_group_rows=250)
    meta = parquet.read_footer(parquet.BytesSource(data))
    sch = meta.schema
    ids = t.column("id").values
    stats = [rg.column_stats(sch) for rg in meta.row_groups]
    # a value inside every row group\'s [min, max] but present in exactly
    # one: stats say SOME everywhere, the bloom refutes the rest
    target = int(ids[len(ids) // 2])
    eq_verdicts = [(field("id") == target).prune(st) for st in stats]
    assert SOME in eq_verdicts
    assert eq_verdicts.count(NONE) >= len(stats) - 2
    isin = IsIn("id", [target])
    assert [isin.prune(st) for st in stats].count(NONE) >= len(stats) - 2
    bl = BloomIn.build("id", np.asarray([target], np.int64))
    bv = [bl.prune(st) for st in stats]
    assert SOME in bv and bv.count(NONE) >= len(stats) - 2
    # soundness: the row group that holds the value is never NONE
    hold = [i for i, rg in enumerate(meta.row_groups)
            if target in parquet.scan_row_group(
                parquet.BytesSource(data), meta, rg,
                ["id"]).column("id").values]
    for i in hold:
        assert eq_verdicts[i] != NONE
        assert bv[i] != NONE


def test_bloom_cross_kind_probe_is_skipped():
    t = _table(500)
    data = parquet.write_table(t, row_group_rows=500)
    meta = parquet.read_footer(parquet.BytesSource(data))
    st = meta.row_groups[0].column_stats(meta.schema)
    # float-keyed bloom probing the int64 "id" column: key domains
    # differ, so the index must NOT be consulted (stays SOME)
    bl = BloomIn.build("id", np.asarray([0.5, 1.5], np.float64))
    assert bl.key_kind == "f"
    assert bl.prune({"id": st["id"]}) == SOME


def test_bloom_wire_form_unchanged():
    bl = BloomIn.build("id", np.arange(10, dtype=np.int64))
    d = bl.to_json()
    assert "words" not in d and "key_kind" not in d
    from repro.aformat.expressions import Expr

    back = Expr.from_json(d)
    assert back.bits == bl.bits and back.words is None


# ---------------------------------------------------------------------------
# the soundness differential: with/without indexes, all formats
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("layout", ["flat", "striped", "split"])
@pytest.mark.parametrize("fmt", ["parquet", "pushdown", "adaptive"])
def test_index_pruning_soundness_differential(layout, fmt):
    """Whatever the index refutes must genuinely be absent: scanning
    with index blocks and scanning a physically identical no-index copy
    returns byte-identical rows, for every format x layout."""
    t = _table(3000, seed=3)
    ids = t.column("id").values
    fs_a, fs_b = make_cluster(4), make_cluster(4)
    WRITERS[layout](fs_a, "/d/t.arw", t, row_group_rows=250)
    if layout == "split":
        # split\'s per-rg files still index; the .index sidecar is stats-only
        WRITERS[layout](fs_b, "/d/t.arw", t, row_group_rows=250)
    else:
        data = parquet.write_table(t, row_group_rows=250,
                                   build_indexes=False)
        # write the same physical bytes minus index blocks
        if layout == "flat":
            su = max(4096, -(-len(data) // 4096) * 4096)
            fs_b.write_file("/d/t.arw", data, stripe_unit=su,
                            xattrs={"layout": "flat"})
        else:
            WRITERS[layout](fs_b, "/d/t.arw", t, row_group_rows=250)
    present = int(ids[17])
    absent = int(ids.max()) + 7   # inside no row group
    for target, expect_rows in ((present, 1), (absent, 0)):
        outs = []
        for fs in (fs_a, fs_b):
            ds = dataset(fs, "/d")
            out = ds.scanner(format=fmt,
                             predicate=(field("id") == target),
                             num_threads=2).to_table()
            outs.append(out)
        for out in outs:
            assert len(out) == expect_rows
        if expect_rows:
            for out in outs:
                assert out.column("id").values[0] == target
                row = int(np.flatnonzero(ids == target)[0])
                assert out.column("tag").values[0] == \
                    t.column("tag").values[row]


def test_point_lookup_wire_savings_client_format():
    """The acceptance bar: a bloom-indexed point lookup over a
    high-cardinality column ships <=10% of the stats-only wire bytes in
    the client-side format (chunk reads are the wire)."""
    t = _table(16_000, seed=9)
    ids = t.column("id").values
    fs_idx, fs_plain = make_cluster(4), make_cluster(4)
    write_flat(fs_idx, "/d/t.arw", t, row_group_rows=250)
    data = parquet.write_table(t, row_group_rows=250,
                               build_indexes=False)
    su = max(4096, -(-len(data) // 4096) * 4096)
    fs_plain.write_file("/d/t.arw", data, stripe_unit=su,
                        xattrs={"layout": "flat"})
    target = int(ids[31])
    wire = {}
    for name, fs in (("indexed", fs_idx), ("plain", fs_plain)):
        ds = dataset(fs, "/d")
        sc = ds.scanner(format="parquet",
                        predicate=(field("id") == target),
                        num_threads=2)
        out = sc.to_table()
        assert len(out) == 1 and out.column("id").values[0] == target
        wire[name] = sc.metrics.wire_bytes - sc.metrics.discovery_bytes
    assert wire["indexed"] <= 0.10 * wire["plain"], wire


def test_explain_names_index_verdicts(fs):
    t = _table(3000, seed=5)
    write_flat(fs, "/e/t.arw", t, row_group_rows=250)
    ds = dataset(fs, "/e")
    target = int(t.column("id").values[100])
    text = ds.query(format="pushdown").filter(
        field("id") == target).explain()
    assert "bloom index proves NONE" in text
    assert "by bloom index" in text


def test_scan_metrics_count_index_pruned(fs):
    t = _table(3000, seed=6)
    write_flat(fs, "/m/t.arw", t, row_group_rows=250)
    ds = dataset(fs, "/m")
    target = int(t.column("id").values[7])
    q = ds.query(format="pushdown").filter(field("id") == target)
    out = q.to_table()
    assert len(out) == 1
    s = q.metrics.summary()
    assert s["index_pruned"] >= s["pruned"] - 2 > 0
