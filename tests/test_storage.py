"""Storage layer: placement, replication, failover, striping, DOA, layouts."""

import time

import numpy as np
import pytest

from repro.aformat import parquet
from repro.aformat.table import Table
from repro.storage import layouts
from repro.storage.cephfs import DirectObjectAccess, FileSource
from repro.storage.objclass import register_default_classes
from repro.storage.objstore import ObjectNotFound, ObjectStore, OSDDownError


# ---------------------------------------------------------------------------
# object store
# ---------------------------------------------------------------------------


def test_replication_and_placement():
    store = ObjectStore(8, replication=3)
    store.put("obj1", b"hello")
    acting = store.acting_set("obj1")
    assert len(acting) == 3
    assert len({o.osd_id for o in acting}) == 3
    # deterministic placement
    assert [o.osd_id for o in store.acting_set("obj1")] == \
        [o.osd_id for o in acting]
    for o in acting:
        assert o.contains("obj1")


def test_placement_is_balanced():
    store = ObjectStore(8, replication=3)
    for i in range(400):
        store.put(f"o{i}", b"x" * 10)
    counts = [o.stats.objects for o in store.osds]
    assert min(counts) > 0
    assert max(counts) < 3 * 400 / 8 * 2.5   # no pathological skew


def test_failover_read():
    store = ObjectStore(4, replication=3)
    store.put("k", b"data")
    primary = store.primary_of("k")
    store.fail_osd(primary.osd_id)
    assert store.get("k") == b"data"          # replica serves the read
    with pytest.raises(ObjectNotFound):
        store.get("nonexistent")


def test_write_quorum():
    store = ObjectStore(3, replication=3)
    store.put("a", b"1")
    acting = store.acting_set("a")
    store.fail_osd(acting[0].osd_id)
    store.put("b", b"2")                       # 2/3 still a quorum
    store.fail_osd(acting[1].osd_id)
    with pytest.raises(OSDDownError):
        store.put("c", b"3")


def test_recover_osd_heals():
    store = ObjectStore(4, replication=3)
    for i in range(50):
        store.put(f"o{i}", bytes([i]))
    store.fail_osd(1)
    for i in range(50, 60):
        store.put(f"o{i}", bytes([i]))
    healed = store.recover_osd(1)
    assert healed > 0
    assert store.scrub() == []


def test_recover_osd_repairs_stale_replica():
    """An object overwritten while a replica was down leaves that replica
    holding *stale bytes* (not missing ones) — recovery must detect it by
    version and re-replicate, and must sync the version counter rather
    than put-bump it (a bump would spuriously invalidate result caches)."""
    store = ObjectStore(4, replication=3)
    store.put("k", b"old")
    acting = store.acting_set("k")
    victim = acting[1]
    store.fail_osd(victim.osd_id)
    store.put("k", b"new-bytes")               # peers move to version 2
    peer_version = store.version_of("k")
    healed = store.recover_osd(victim.osd_id)
    assert healed >= 1
    assert victim.peek("k") == b"new-bytes"    # stale copy re-replicated
    assert victim.version("k") == peer_version  # synced, not bumped
    assert store.version_of("k") == peer_version  # cache keys undisturbed
    assert store.scrub() == []


def test_recover_osd_drops_deleted_objects():
    """An object deleted cluster-wide while a replica was down must be
    removed on recovery, not resurrected."""
    store = ObjectStore(4, replication=3)
    store.put("gone", b"bytes")
    victim = store.acting_set("gone")[1]
    store.fail_osd(victim.osd_id)
    store.delete("gone")
    store.recover_osd(victim.osd_id)
    assert not victim.contains("gone")
    assert not store.exists("gone")


def test_scrub_leaves_client_counters_untouched():
    """Replica verification is background traffic: it must not inflate the
    reads/bytes_read stats the Fig.-6 accounting replays as client load."""
    store = ObjectStore(4, replication=3)
    for i in range(20):
        store.put(f"o{i}", b"x" * 100)
    before = [(o.stats.reads, o.stats.bytes_read) for o in store.osds]
    assert store.scrub() == []
    after = [(o.stats.reads, o.stats.bytes_read) for o in store.osds]
    assert before == after


def test_scrub_detects_corruption():
    store = ObjectStore(4, replication=3)
    store.put("x", b"good")
    victim = store.acting_set("x")[1]
    victim._objects["x"] = b"evil"            # bit-rot injection
    assert store.scrub() == ["x"]


def test_cls_call_runs_on_storage_node():
    store = register_default_classes(ObjectStore(4))
    store.put("obj", b"payload")
    out, osd_id, el = store.cls_call("obj", "checksum_op")
    import zlib, struct
    assert struct.unpack("<I", out)[0] == zlib.crc32(b"payload")
    assert osd_id in [o.osd_id for o in store.acting_set("obj")]
    assert store.osds[osd_id].stats.cls_calls == 1
    assert store.osds[osd_id].stats.busy_s > 0


# ---------------------------------------------------------------------------
# CephFS striping + DirectObjectAccess
# ---------------------------------------------------------------------------


def test_striping_roundtrip(fs):
    data = bytes(range(256)) * 5000            # 1.28 MB
    fs.write_file("/f", data, stripe_unit=100_000)
    ino = fs.stat("/f")
    assert ino.object_count == -(-len(data) // 100_000)
    assert fs.read_file("/f") == data
    # random-access range reads across stripe boundaries
    for off, ln in [(0, 10), (99_990, 30), (250_000, 123), (len(data) - 5, 5)]:
        assert fs.read_range("/f", off, ln) == data[off:off + ln]


def test_direct_object_access_translation(fs):
    data = b"ab" * 150_000
    fs.write_file("/x", data, stripe_unit=65536)
    doa = DirectObjectAccess(fs)
    ids = doa.object_ids("/x")
    assert len(ids) == fs.stat("/x").object_count
    # every id resolves in the store and concatenates back to the file
    assert b"".join(fs.store.get(i) for i in ids)[:len(data)] == data


def test_hedged_call_accounts_both(fs):
    tbl = Table.from_pydict({"x": np.arange(100, dtype=np.int64)})
    layouts.write_flat(fs, "/h.arw", tbl)
    doa = DirectObjectAccess(fs)
    name = fs.object_names("/h.arw")[0]
    primary = fs.store.primary_of(name)
    primary.straggle_factor = 1e6              # pathological straggler
    res, osd_id, el, hedged = doa.call_hedged(
        "/h.arw", 0, "scan_op", {"columns": ["x"]},
        hedge_threshold_s=1e-5)
    assert hedged
    assert osd_id != primary.osd_id            # replica won
    assert Table.from_ipc(res).num_rows == 100
    # the losing primary keeps running; once it lands, its duplicated
    # service time is booked as hedge waste
    deadline = time.perf_counter() + 2.0
    while (primary.stats.hedge_wasted_s == 0
           and time.perf_counter() < deadline):
        time.sleep(0.01)
    assert primary.stats.hedge_wasted_s > 0


def test_hedged_wall_time_overlaps_straggler(fs):
    """The race property itself: wall time of a hedged call against a
    straggler is ~(deadline + backup), strictly less than the straggler's
    own *real* service time — never primary + backup as the old
    sequential implementation cost."""
    tbl = Table.from_pydict({"x": np.arange(20_000, dtype=np.int64)})
    layouts.write_flat(fs, "/w.arw", tbl)
    doa = DirectObjectAccess(fs)
    name = fs.object_names("/w.arw")[0]
    primary = fs.store.primary_of(name)
    primary.straggle_factor = 1e6
    primary.max_straggle_delay_s = 0.5         # straggler really sleeps this
    t0 = time.perf_counter()
    res, osd_id, el, hedged = doa.call_hedged(
        "/w.arw", 0, "scan_op", {"columns": ["x"]},
        hedge_threshold_s=0.02)
    wall = time.perf_counter() - t0
    assert hedged and osd_id != primary.osd_id
    # generous margin for a loaded CI host: still far below the 0.5 s the
    # primary is provably sleeping (and below primary + backup)
    assert wall < 0.4
    assert Table.from_ipc(res).num_rows == 20_000


def test_hedged_call_fast_primary_never_hedges(fs):
    tbl = Table.from_pydict({"x": np.arange(100, dtype=np.int64)})
    layouts.write_flat(fs, "/f.arw", tbl)
    doa = DirectObjectAccess(fs)
    res, osd_id, el, hedged = doa.call_hedged(
        "/f.arw", 0, "scan_op", {"columns": ["x"]}, hedge_threshold_s=5.0)
    assert not hedged
    assert Table.from_ipc(res).num_rows == 100


# ---------------------------------------------------------------------------
# layouts: striped / split / flat self-containment
# ---------------------------------------------------------------------------


@pytest.fixture
def small_table():
    rng = np.random.default_rng(7)
    return Table.from_pydict({
        "a": np.arange(3000, dtype=np.int64),
        "b": rng.normal(size=3000).astype(np.float32),
    })


def test_striped_layout_self_contained(fs, small_table):
    meta = layouts.write_striped(fs, "/s.arw", small_table,
                                 row_group_rows=512)
    ino = fs.stat("/s.arw")
    assert ino.stripe_unit == meta.stripe_unit
    footer = layouts.read_striped_footer(fs, "/s.arw")
    assert footer.num_rows == 3000
    for i, rg in enumerate(footer.row_groups):
        first = rg.chunks[0].offset // ino.stripe_unit
        last = (rg.chunks[-1].offset
                + sum(rg.chunks[-1].buffer_lengths) - 1) // ino.stripe_unit
        assert first == last == meta.rg_objects[i]   # never spans objects


def test_striped_scan_matches(fs, small_table):
    layouts.write_striped(fs, "/s.arw", small_table, row_group_rows=512)
    footer = layouts.read_striped_footer(fs, "/s.arw")
    src = FileSource(fs, "/s.arw")
    back = parquet.scan_file(src, meta=footer)
    assert back.equals(small_table)


def test_split_layout(fs, small_table):
    index_path = layouts.write_split(fs, "/p.arw", small_table,
                                     row_group_rows=512)
    idx = layouts.read_split_index(fs, index_path)
    assert len(idx.row_groups) == -(-3000 // 512)
    parts = []
    for rg in idx.row_groups:
        sub = fs.read_file(rg["file"])
        parts.append(parquet.scan_file(parquet.BytesSource(sub)))
        assert fs.stat(rg["file"]).object_count == 1   # one object per part
    assert Table.concat(parts).equals(small_table)


def test_flat_layout_single_object(fs, small_table):
    layouts.write_flat(fs, "/f.arw", small_table, row_group_rows=512)
    assert fs.stat("/f.arw").object_count == 1
    back = parquet.scan_file(FileSource(fs, "/f.arw"))
    assert back.equals(small_table)


# ---------------------------------------------------------------------------
# delete accounting, versioned CAS, peer access (mutable-dataset substrate)
# ---------------------------------------------------------------------------


def test_delete_keeps_stored_bytes_exact():
    """Deleting an object must remove its bytes/objects from every up
    replica's accounting (the capacity view maintenance and the Fig.-6
    replay read)."""
    store = ObjectStore(8, replication=3)
    base = [(o.stats.bytes_stored, o.stats.objects) for o in store.osds]
    store.put("victim", b"x" * 5000)
    assert store.total_stats().bytes_stored == 3 * 5000
    dropped = store.delete("victim")
    assert dropped == 3
    assert [(o.stats.bytes_stored, o.stats.objects)
            for o in store.osds] == base
    assert store.total_stats().bytes_stored == 0
    assert not store.exists("victim")


def test_delete_with_down_replica_heals_exactly():
    """A replica that is down during the delete keeps counting the
    object's bytes; it must never serve membership while down, and
    recovery must settle its accounting to exact."""
    store = ObjectStore(4, replication=3)
    store.put("victim", b"y" * 4096)
    acting = store.acting_set("victim")
    down = acting[1]
    store.fail_osd(down.osd_id)
    store.delete("victim")
    # the down replica still counts the bytes (it cannot know) ...
    assert down.stats.bytes_stored == 4096
    # ... but the cluster-facing views must not resurrect the object
    assert not store.exists("victim")
    assert "victim" not in store.list_objects()
    # version advanced on the up replicas: any cache keyed on it is dead
    assert store.version_of("victim") == 2
    store.recover_osd(down.osd_id)
    assert down.stats.bytes_stored == 0
    assert down.stats.objects == 0
    assert not down.contains("victim")
    assert store.total_stats().bytes_stored == 0


def test_put_if_version_optimistic_commit():
    from repro.storage.objstore import VersionConflictError

    store = ObjectStore(4, replication=3)
    assert store.put_if_version("head", b"v1", 0) == 1
    assert store.put_if_version("head", b"v2", 1) == 2
    with pytest.raises(VersionConflictError) as ei:
        store.put_if_version("head", b"stale", 1)
    assert ei.value.expected == 1 and ei.value.actual == 2
    assert store.get("head") == b"v2"
    # create-if-absent semantics: expected 0 conflicts once it exists
    with pytest.raises(VersionConflictError):
        store.put_if_version("head", b"v3", 0)


def test_object_handle_peer_access_counters():
    """compact_op's reads are cluster-internal: open_peer + peek_all must
    not inflate client-visible read counters, and a non-co-located peer
    is a hard miss."""
    from repro.storage.objstore import ObjectHandle

    store = ObjectStore(8, replication=2)
    store.put("a", b"alpha")
    # find a peer object actually co-located with "a"
    holder = store.acting_set("a")[0]
    peer_name = None
    for i in range(64):
        cand = f"peer{i}"
        if holder in store.acting_set(cand):
            store.put(cand, b"beta")
            peer_name = cand
            break
    assert peer_name is not None
    h = ObjectHandle(holder, "a")
    reads_before = holder.stats.reads
    assert h.peek_all() == b"alpha"
    assert h.open_peer(peer_name).peek_all() == b"beta"
    assert holder.stats.reads == reads_before
    with pytest.raises(ObjectNotFound):
        h.open_peer("never-written")


def test_compact_op_rejects_non_colocated_sources(fs, small_table):
    """A compact_op naming a source the executing OSD does not hold must
    refuse (the driver falls back), never crash or partially write."""
    import json

    layouts.write_flat(fs, "/one.arw", small_table.slice(0, 100),
                       row_group_rows=100)
    name = fs.object_names("/one.arw")[0]
    payload = {"sources": [{"name": name, "keep": None},
                           {"name": "not-an-object", "keep": None}],
               "target": "t", "row_group_rows": 100}
    raw, _osd, _el = fs.store.cls_call(name, "compact_op", payload)
    reply = json.loads(raw)
    assert reply == {"ok": False, "missing": ["not-an-object"]}
    assert not fs.store.exists("t")


# ---------------------------------------------------------------------------
# layouts: the row-group-within-one-object knob validation
# ---------------------------------------------------------------------------


def test_write_striped_object_size_too_small_raises(fs, small_table):
    with pytest.raises(ValueError) as ei:
        layouts.write_striped(fs, "/s.arw", small_table,
                              row_group_rows=2048, object_size=4096)
    msg = str(ei.value)
    assert "row_group_rows" in msg and "object_size" in msg


def test_write_striped_object_size_respected(fs, small_table):
    meta = layouts.write_striped(fs, "/s.arw", small_table,
                                 row_group_rows=256,
                                 object_size=64 * 4096)
    assert meta.stripe_unit == 64 * 4096
    footer = layouts.read_striped_footer(fs, "/s.arw")
    back = parquet.scan_file(FileSource(fs, "/s.arw"), meta=footer)
    assert back.equals(small_table)


def test_write_striped_object_size_misaligned_raises(fs, small_table):
    with pytest.raises(ValueError, match="alignment"):
        layouts.write_striped(fs, "/s.arw", small_table,
                              row_group_rows=256, object_size=5000)


def test_write_split_object_size_too_small_raises(fs, small_table):
    with pytest.raises(ValueError) as ei:
        layouts.write_split(fs, "/p.arw", small_table,
                            row_group_rows=2048, object_size=4096)
    msg = str(ei.value)
    assert "row_group_rows" in msg and "object_size" in msg


def test_write_split_object_size_respected(fs, small_table):
    index_path = layouts.write_split(fs, "/p.arw", small_table,
                                     row_group_rows=256,
                                     object_size=32 * 4096)
    idx = layouts.read_split_index(fs, index_path)
    for rg in idx.row_groups:
        ino = fs.stat(rg["file"])
        assert ino.stripe_unit == 32 * 4096
        assert ino.object_count == 1
