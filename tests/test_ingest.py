"""The ingest plane: deterministic sharding, byte-exact resume through
the checkpoint layer, elastic re-sharding, and QoS coexistence.

The contracts under test are what makes the reader trustworthy as the
training input path: the shard partition is a pure function of the plan
(every fragment exactly once, any dp_size, empty shards legal); a reader
restored from a ReaderState — including one that round-tripped through
CheckpointManager on a snapshot-pinned mutable dataset with a concurrent
append in flight — emits a byte-identical batch stream; a mid-epoch
downsize hands the unconsumed remainder to the survivors exactly once
(orphaned packing buffers adopted, not dropped); and ingest runs as a
bulk tenant that never starves an interactive scanner.
"""

import threading
import types

import numpy as np
import pytest

from repro.aformat.expressions import field
from repro.aformat.table import Table
from repro.core import MutableDataset, dataset, make_cluster
from repro.data import synth_corpus, write_corpus
from repro.dataset.plan import partition_tasks
from repro.dataset.qos import TenantRegistry, ingest_context
from repro.distrib import ANY_SHAPE, CheckpointManager, plan_downsize
from repro.ingest import (ReaderConfig, ReaderState, ShardedReader,
                          epoch_order, reshard_states)

FORMATS = ["parquet", "pushdown", "adaptive"]


@pytest.fixture(scope="module")
def corpus_fs():
    fs = make_cluster(4)
    tbl = synth_corpus(300, mean_doc_len=200, vocab_size=1000, seed=3)
    write_corpus(fs, "/c", tbl, num_shards=4, row_group_rows=4096)
    return fs, tbl


def take(reader, n):
    return [next(reader) for _ in range(n)]


def assert_same_batches(a, b):
    assert len(a) == len(b)
    for x, y in zip(a, b):
        assert np.array_equal(x["tokens"], y["tokens"])
        assert np.array_equal(x["labels"], y["labels"])


# ---------------------------------------------------------------------------
# shard partition properties
# ---------------------------------------------------------------------------


def test_partition_every_task_exactly_once(corpus_fs):
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    r = ShardedReader(ds, ReaderConfig(seq_len=32, local_batch=2))
    tasks = r.tasks
    r.close()
    assert len(tasks) > 4
    for dp in (1, 2, 3, 5, 7, 64):
        shards = partition_tasks(tasks, dp)
        assert len(shards) == dp
        flat = [i for s in shards for i in s]
        assert sorted(flat) == list(range(len(tasks)))  # exactly once
        for s in shards:
            assert s == sorted(s)  # plan order within a shard
        # deterministic: same inputs, same partition
        assert partition_tasks(tasks, dp) == shards


def test_partition_row_balanced(corpus_fs):
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    r = ShardedReader(ds, ReaderConfig(seq_len=32, local_batch=2))
    tasks = r.tasks
    r.close()
    shards = partition_tasks(tasks, 3)
    loads = [sum(tasks[i].fragment.num_rows for i in s) for s in shards]
    biggest = max(t.fragment.num_rows for t in tasks)
    # greedy LPT: no two shards differ by more than one fragment
    assert max(loads) - min(loads) <= biggest


def test_partition_empty_and_edge_cases():
    assert partition_tasks([], 4) == [[], [], [], []]
    with pytest.raises(ValueError):
        partition_tasks([], 0)


def test_more_ranks_than_fragments_is_legal(corpus_fs):
    """The old TokenPipeline crashed here; empty shards must idle."""
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    cfg = ReaderConfig(seq_len=32, local_batch=2)
    probe = ShardedReader(ds, cfg)
    n = len(probe.tasks)
    probe.close()
    dp = n + 5
    covered = []
    empties = 0
    for rank in range(dp):
        rd = ShardedReader(ds, cfg, dp_rank=rank, dp_size=dp)
        covered.extend(rd.shard)
        if not rd.shard:
            empties += 1
            assert list(rd.batches()) == []  # yields nothing, no crash
        rd.close()
    assert empties == 5
    assert sorted(covered) == list(range(n))


# ---------------------------------------------------------------------------
# checkpoint / restore determinism
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("fmt", FORMATS)
def test_resume_byte_identical(corpus_fs, fmt):
    """Kill after N batches, restore from the checkpoint state: the
    continuation is byte-identical to the uninterrupted stream — across
    every placement (client, storage, scheduler-placed)."""
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    cfg = ReaderConfig(seq_len=48, local_batch=2, format=fmt,
                       predicate=field("quality") > 0.4, seed=9,
                       num_threads=2)
    ref = ShardedReader(ds, cfg)
    full = take(ref, 12)
    ref.close()

    a = ShardedReader(ds, cfg)
    head = take(a, 5)
    st = a.checkpoint()
    a.close()  # the "kill": prefetched-but-undelivered batches are lost

    b = ShardedReader(ds, cfg, state=ReaderState.from_arrays(st.to_arrays()))
    tail = take(b, 7)
    b.close()
    assert_same_batches(head + tail, full)


def test_resume_spans_epoch_boundary(corpus_fs):
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    # big batches + a half-size shard: one epoch is only a few batches
    cfg = ReaderConfig(seq_len=512, local_batch=8, seed=1, num_threads=2)
    ref = ShardedReader(ds, cfg, dp_rank=1, dp_size=2)
    full = take(ref, 20)
    assert ref.checkpoint().epoch >= 1  # proved we crossed an epoch
    ref.close()
    a = ShardedReader(ds, cfg, dp_rank=1, dp_size=2)
    head = take(a, 9)
    st = a.checkpoint()
    a.close()
    b = ShardedReader(ds, cfg, state=st)
    tail = take(b, 11)
    b.close()
    assert_same_batches(head + tail, full)


def test_state_arrays_roundtrip():
    for override in (None, np.array([4, 1, 7], np.int64)):
        st = ReaderState(seed=3, dp_rank=1, dp_size=4, epoch=2, cursor=5,
                         snapshot_id=8, n_tasks=40,
                         buffer=np.arange(13, dtype=np.int32),
                         override=override)
        rt = ReaderState.from_arrays(st.to_arrays())
        assert dataclasses_equal(st, rt)


def dataclasses_equal(a: ReaderState, b: ReaderState) -> bool:
    if (a.seed, a.dp_rank, a.dp_size, a.epoch, a.cursor, a.snapshot_id,
            a.n_tasks) != (b.seed, b.dp_rank, b.dp_size, b.epoch,
                           b.cursor, b.snapshot_id, b.n_tasks):
        return False
    if not np.array_equal(a.buffer, b.buffer):
        return False
    if (a.override is None) != (b.override is None):
        return False
    return a.override is None or np.array_equal(a.override, b.override)


def test_state_version_and_plan_guards(corpus_fs):
    arrays = ReaderState(seed=0, dp_rank=0, dp_size=1).to_arrays()
    arrays["meta"] = arrays["meta"].copy()
    arrays["meta"][0] = 99
    with pytest.raises(ValueError, match="version"):
        ReaderState.from_arrays(arrays)
    # a state cut from a different plan shape is refused, not misread
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    st = ReaderState(seed=0, dp_rank=0, dp_size=1, n_tasks=3)
    with pytest.raises(ValueError, match="task"):
        ShardedReader(ds, ReaderConfig(seq_len=32, local_batch=2),
                      state=st)


def test_checkpoint_manager_any_shape(corpus_fs):
    """ANY_SHAPE restores a leaf whose shape can't be known up front
    (the variable-length packing buffer); exact structs still enforce."""
    fs, _ = corpus_fs
    cm = CheckpointManager(fs, "/ckpt_any", keep=2)
    cm.save({"buf": np.arange(7, dtype=np.int32)}, 1)
    out = cm.restore({"buf": ANY_SHAPE}, 1)
    assert np.array_equal(out["buf"], np.arange(7, dtype=np.int32))
    with pytest.raises(ValueError, match="expected"):
        cm.restore({"buf": np.zeros(3, np.int32)}, 1)


def test_resume_on_snapshot_pinned_mutable_dataset(corpus_fs):
    """The acceptance criterion: reader state round-trips through
    CheckpointManager alongside a model pytree, on a MutableDataset,
    with a concurrent append landing between checkpoint and restore —
    the restored stream is byte-identical because as_of() pins the
    snapshot the run started from; only a *fresh* reader sees the new
    data."""
    fs, tbl = corpus_fs
    md = MutableDataset.create(fs, "/mut_ingest")
    md.append(tbl, row_group_rows=4096)
    cfg = ReaderConfig(seq_len=48, local_batch=2, seed=4, num_threads=2)

    ref = ShardedReader(md, cfg)
    full = take(ref, 10)
    ref.close()

    a = ShardedReader(md, cfg)
    head = take(a, 4)
    cm = CheckpointManager(fs, "/ckpt_ing", keep=2)
    model = {"w": np.ones((3, 3), np.float32), "step": np.int64(4)}
    cm.save({"model": model, "reader": a.checkpoint().to_arrays()}, 4)
    a.close()

    # a commit lands while the job is down
    extra = synth_corpus(80, mean_doc_len=150, vocab_size=1000, seed=77)
    md.append(extra, row_group_rows=4096)

    restored = cm.restore({"model": {"w": np.zeros((3, 3), np.float32),
                                     "step": np.int64(0)},
                           "reader": ReaderState.restore_structs()}, 4)
    assert np.array_equal(restored["model"]["w"], model["w"])
    rstate = ReaderState.from_arrays(restored["reader"])
    b = ShardedReader(md, cfg, state=rstate)
    assert b.snapshot_id == rstate.snapshot_id  # pinned, not HEAD
    tail = take(b, 6)
    b.close()
    assert_same_batches(head + tail, full)

    # un-pinned readers do see the append
    fresh = ShardedReader(md, cfg)
    assert len(fresh.tasks) > len(b.tasks)
    assert fresh.snapshot_id > rstate.snapshot_id
    fresh.close()


# ---------------------------------------------------------------------------
# elastic re-sharding
# ---------------------------------------------------------------------------


def mesh_stub(data=4, model=1):
    # plan_downsize only reads axis_names and shape — a stub stands in
    # for a real 4-device mesh on this 1-CPU test host
    return types.SimpleNamespace(axis_names=("data", "model"),
                                 shape={"data": data, "model": model})


def test_downsize_covers_remainder_exactly_once(corpus_fs):
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    cfg = ReaderConfig(seq_len=32, local_batch=2, seed=6, num_threads=2)
    readers = [ShardedReader(ds, cfg, dp_rank=r, dp_size=4)
               for r in range(4)]
    for r in readers:
        take(r, 2)  # mid-epoch on every rank
    states = [r.checkpoint() for r in readers]
    shards = readers[0].shards
    tasks = readers[0].tasks
    for r in readers:
        r.close()

    plan = plan_downsize(mesh_stub(4, 1), healthy_devices=2)
    new_dp = plan.axis_size("data")
    assert new_dp == 2
    new_states = reshard_states(ds, cfg, states, new_dp)
    assert [s.dp_rank for s in new_states] == [0, 1]

    consumed = []
    for s in states:
        consumed.extend(epoch_order(s, shards)[:s.cursor])
    handed = [int(i) for s in new_states for i in s.override]
    # consumed ∪ handed == the whole epoch, disjointly
    assert sorted(consumed + handed) == sorted(
        i for sh in shards for i in sh)

    # token conservation: pending rows + every rank's packing remainder
    # all land somewhere (dead ranks' buffers adopted, not dropped)
    pending_rows = sum(tasks[i].fragment.num_rows for i in handed)
    assert pending_rows == sum(
        tasks[i].fragment.num_rows
        for s in states for i in epoch_order(s, shards)[s.cursor:])
    assert sum(len(s.buffer) for s in new_states) == \
        sum(len(s.buffer) for s in states)

    # survivors actually stream from the handed-off remainder
    for s in new_states:
        rd = ShardedReader(ds, cfg, state=s)
        batch = next(rd)
        assert batch["tokens"].shape == (2, 32)
        rd.close()


def test_downsize_validation(corpus_fs):
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    cfg = ReaderConfig(seq_len=32, local_batch=2)
    states = [ReaderState(seed=0, dp_rank=r, dp_size=4) for r in range(3)]
    with pytest.raises(ValueError, match="all 4 ranks"):
        reshard_states(ds, cfg, states, 2)
    bad = [ReaderState(seed=0, dp_rank=0, dp_size=2),
           ReaderState(seed=1, dp_rank=1, dp_size=2)]
    with pytest.raises(ValueError, match="disagree"):
        reshard_states(ds, cfg, bad, 1)
    with pytest.raises(ValueError, match="at least one"):
        reshard_states(ds, cfg, [], 1)


def test_downsize_to_one_rank_mid_epoch(corpus_fs):
    """Extreme shrink: a single survivor inherits every rank's
    remainder and keeps streaming."""
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    cfg = ReaderConfig(seq_len=32, local_batch=2, seed=2, num_threads=2)
    readers = [ShardedReader(ds, cfg, dp_rank=r, dp_size=3)
               for r in range(3)]
    take(readers[0], 3)  # ranks at *different* cut points
    take(readers[1], 1)
    states = [r.checkpoint() for r in readers]
    for r in readers:
        r.close()
    (lone,) = reshard_states(ds, cfg, states, 1)
    assert lone.dp_rank == 0 and lone.dp_size == 1
    rd = ShardedReader(ds, cfg, state=lone)
    out = take(rd, 5)
    rd.close()
    assert all(b["tokens"].dtype == np.int32 for b in out)


# ---------------------------------------------------------------------------
# QoS: ingest as a bulk tenant
# ---------------------------------------------------------------------------


def test_ingest_tenant_does_not_starve_interactive(corpus_fs):
    """A training reader hammering the cluster as the registered bulk
    'ingest' tenant must not starve a deadline-carrying interactive
    tenant: every interactive query completes with a Table, never a
    Shed, while ingest streams concurrently."""
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    registry = TenantRegistry(slots_per_osd=2)
    registry.register("dash", weight=4.0, lane="interactive",
                      deadline_s=5.0)
    cfg = ReaderConfig(seq_len=64, local_batch=4, num_threads=4,
                       registry=registry)
    reader = ShardedReader(ds, cfg)
    assert reader.ctx.tenant == "ingest" and reader.ctx.lane == "bulk"

    stop = threading.Event()

    def churn():
        while not stop.is_set():
            next(reader)

    t = threading.Thread(target=churn, daemon=True)
    t.start()
    try:
        for _ in range(5):
            out = ds.query(tenant=registry.context("dash"),
                           num_threads=2).filter(
                field("quality") > 0.5).select("token").to_table()
            assert isinstance(out, Table), f"interactive shed: {out}"
            assert len(out) > 0
    finally:
        stop.set()
        reader.close()
        t.join(timeout=10.0)
    assert not t.is_alive()
    # the registry saw both tenants
    seen = registry.by_tenant()
    assert "dash" in seen and "ingest" in seen


def test_ingest_context_registration():
    registry = TenantRegistry()
    ctx = ingest_context(registry)
    assert ctx.tenant == "ingest" and ctx.lane == "bulk"
    assert ctx.registry is registry
    # idempotent: a second reader reuses the spec
    assert ingest_context(registry).tenant == "ingest"
    assert registry.spec("ingest").lane == "bulk"
    # registry-free fallback still tags the lane
    solo = ingest_context(None)
    assert solo.tenant == "ingest" and solo.registry is None


# ---------------------------------------------------------------------------
# reader surface
# ---------------------------------------------------------------------------


def test_stats_surface(corpus_fs):
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    rd = ShardedReader(ds, ReaderConfig(seq_len=32, local_batch=2))
    take(rd, 3)
    st = rd.stats()
    rd.close()
    for key in ("fragments_scanned", "client_cpu_s", "osd_cpu_s",
                "wire_bytes", "rows", "batches", "epochs"):
        assert key in st
    assert st["rows"] > 0 and st["batches"] >= 3


def test_reader_context_manager(corpus_fs):
    fs, _ = corpus_fs
    ds = dataset(fs, "/c")
    with ShardedReader(ds, ReaderConfig(seq_len=32, local_batch=2)) as rd:
        next(rd)
        thread = rd._prefetcher._thread
    assert not thread.is_alive()  # close() joined the prefetch thread
