"""The lazy query-plan API: builder IR, optimizer passes, explain, limit
pushdown, and wrapper equivalence.

The tentpole claim: every Scanner verb lowers through ONE logical plan,
ONE optimizer, and ONE streaming executor — so these tests pin (a) the
builder -> IR mapping, (b) each optimizer pass in isolation, (c) the
explain() rendering, (d) real end-to-end limit early-exit (fragments past
the budget are never scanned), and (e) that the compatibility wrappers
return exactly what the lazy API returns across the layout x format grid.
"""

import numpy as np
import pytest

from repro.aformat.aggregate import AggSpec
from repro.aformat.expressions import field
from repro.core import (
    dataset,
    make_cluster,
    write_flat,
    write_split,
    write_striped,
)
from repro.dataset import (
    Aggregate,
    Count,
    Filter,
    Limit,
    Project,
    Scan,
)
from repro.dataset.plan import (
    prune_fragments,
    pushdown_limit,
    pushdown_projection,
    rewrite_count,
    rewrite_metadata_aggregate,
    _decompose,
)

WRITERS = {
    "flat": write_flat,
    "striped": write_striped,
    "split": write_split,
}
FORMATS = ["parquet", "pushdown", "adaptive"]


@pytest.fixture(params=["flat", "striped", "split"])
def populated(request, taxi_table):
    fs = make_cluster(8)
    for i in range(4):
        part = taxi_table.slice(i * 5000, 5000)
        WRITERS[request.param](
            fs, f"/d/part{i}.arw", part, row_group_rows=1024
        )
    return fs, taxi_table, request.param


@pytest.fixture
def flat_ds(taxi_table):
    fs = make_cluster(8)
    for i in range(4):
        write_flat(
            fs,
            f"/d/part{i}.arw",
            taxi_table.slice(i * 5000, 5000),
            row_group_rows=1024,
        )
    return fs, dataset(fs, "/d"), taxi_table


# ---------------------------------------------------------------------------
# builder -> IR structure
# ---------------------------------------------------------------------------


def test_builder_constructs_nested_ir(flat_ds):
    fs, ds, _ = flat_ds
    pred = field("fare_amount") > 25.0
    q = ds.query().filter(pred).select("trip_id").limit(10)
    root = q.logical_plan()
    assert isinstance(root, Limit) and root.n == 10
    proj = root.input
    assert isinstance(proj, Project) and proj.columns == ("trip_id",)
    filt = proj.input
    assert isinstance(filt, Filter) and filt.predicate is pred
    assert isinstance(filt.input, Scan) and filt.input.dataset is ds


def test_builder_aggregate_and_count_nodes(flat_ds):
    fs, ds, _ = flat_ds
    q = ds.query().aggregate(["count"], group_by="passenger_count")
    root = q.logical_plan()
    assert isinstance(root, Aggregate)
    assert root.group_by == "passenger_count"
    assert root.specs == (AggSpec("count"),)
    c = ds.query().count().logical_plan()
    assert isinstance(c, Count) and isinstance(c.input, Scan)


def test_builder_is_lazy_and_immutable(flat_ds):
    """Builder verbs derive new queries and never touch storage."""
    fs, ds, _ = flat_ds
    calls = sum(o.stats.cls_calls for o in fs.store.osds)
    base = ds.query()
    derived = base.filter(field("trip_id") < 10).select("trip_id").limit(3)
    assert isinstance(base.logical_plan(), Scan)  # base untouched
    assert isinstance(derived.logical_plan(), Limit)
    assert sum(o.stats.cls_calls for o in fs.store.osds) == calls


def test_builder_validation(flat_ds):
    fs, ds, _ = flat_ds
    with pytest.raises(KeyError):
        ds.query().select("no_such_column")
    with pytest.raises(ValueError):
        ds.query().limit(0)
    with pytest.raises(TypeError):
        ds.query().filter("not an expr")
    agg = ds.query().aggregate(["count"])
    with pytest.raises(ValueError):
        agg.filter(field("trip_id") > 0)
    with pytest.raises(ValueError):
        agg.select("trip_id")
    with pytest.raises(ValueError):
        ds.query().count().aggregate(["count"])
    # aggregating "any n rows" is refused rather than silently answered
    # over the whole input
    with pytest.raises(ValueError, match="limit"):
        ds.query().limit(10).count()
    with pytest.raises(ValueError, match="limit"):
        ds.query().limit(10).aggregate(["count"])
    # limit ON TOP of an aggregate (trim the finalized group rows) is fine
    g = (
        ds.query()
        .aggregate(["count"], group_by="passenger_count")
        .limit(2)
        .to_table()
    )
    assert len(g) == 2


def test_scanner_format_typo_raises_valueerror(flat_ds):
    fs, ds, _ = flat_ds
    with pytest.raises(ValueError, match="parquet"):
        ds.scanner(format="typo")
    with pytest.raises(ValueError, match="adaptive"):
        ds.query(format=42)


# ---------------------------------------------------------------------------
# optimizer passes in isolation
# ---------------------------------------------------------------------------


def test_pass_rewrite_count(flat_ds):
    fs, ds, _ = flat_ds
    root = rewrite_count(Count(Scan(ds)))
    assert isinstance(root, Aggregate)
    assert root.specs == (AggSpec("count"),)
    assert root.group_by is None
    # nested under a limit too
    root = rewrite_count(Limit(Count(Scan(ds)), 5))
    assert isinstance(root, Limit) and isinstance(root.input, Aggregate)


def test_pass_projection_pushdown(flat_ds):
    fs, ds, _ = flat_ds
    spec = _decompose(
        Project(Filter(Scan(ds), field("fare_amount") > 1.0), ("trip_id",))
    )
    cols, _ = pushdown_projection(spec, ds.schema)
    assert cols == ("trip_id",)
    # aggregates narrow to exactly the referenced columns (schema order)
    spec = _decompose(
        Aggregate(
            Scan(ds), (AggSpec("sum", "fare_amount"),), "passenger_count"
        )
    )
    cols, _ = pushdown_projection(spec, ds.schema)
    assert cols == ("passenger_count", "fare_amount")


def test_pass_prune_fragments(flat_ds):
    fs, ds, _ = flat_ds
    frags = ds.fragments()
    # trip_id is monotone: < 1024 keeps exactly the first row group
    kept, pruned = prune_fragments(frags, field("trip_id") < 1024)
    assert len(pruned) == len(frags) - 1
    assert len(kept) == 1
    # the survivor's stats prove ALL, so its residual predicate is gone
    assert kept[0][1] is None
    # predicate-free: nothing pruned, nothing rewritten
    kept, pruned = prune_fragments(frags, None)
    assert len(kept) == len(frags) and not pruned


def test_pass_metadata_rewrite(flat_ds):
    fs, ds, tbl = flat_ds
    frags = ds.fragments()
    survivors = [(f, None) for f in frags]
    # count + integer min/max are provable from footer stats: no tasks
    specs = [AggSpec("count"), AggSpec("min", "trip_id")]
    remaining, state, dec = rewrite_metadata_aggregate(
        survivors, specs, None, ds.schema
    )
    assert not remaining and len(dec) == len(frags)
    assert state.cells == [len(tbl), 0]
    # float min is NOT provable (stats skip non-finite): all fragments stay
    specs = [AggSpec("min", "fare_amount")]
    remaining, state, dec = rewrite_metadata_aggregate(
        survivors, specs, None, ds.schema
    )
    assert len(remaining) == len(frags) and not dec
    # grouped aggregates never rewrite (stats carry no per-key split)
    remaining, _, dec = rewrite_metadata_aggregate(
        survivors, [AggSpec("count")], "passenger_count", ds.schema
    )
    assert len(remaining) == len(frags) and not dec


def test_pass_limit_truncation(flat_ds):
    fs, ds, _ = flat_ds
    frags = ds.fragments()  # 1024 rows each
    survivors = [(f, None) for f in frags]
    kept, dropped, budget = pushdown_limit(survivors, 10)
    assert budget == 10
    assert len(kept) == 1  # first fragment alone guarantees 10 rows
    assert len(dropped) == len(frags) - 1
    # fragments with residual predicates guarantee nothing: all kept
    pred = field("fare_amount") > 1.0
    kept, dropped, _ = pushdown_limit([(f, pred) for f in frags], 10)
    assert len(kept) == len(frags) and not dropped
    # no limit: pass is a no-op
    kept, dropped, budget = pushdown_limit(survivors, None)
    assert len(kept) == len(frags) and budget is None


# ---------------------------------------------------------------------------
# explain(): golden output
# ---------------------------------------------------------------------------


def test_explain_golden():
    rng = np.random.default_rng(7)
    from repro.aformat.table import Table

    tbl = Table.from_pydict(
        {
            "trip_id": np.arange(4096, dtype=np.int64),
            "fare_amount": rng.gamma(2.0, 7.5, 4096).astype(np.float64),
        }
    )
    fs = make_cluster(4)
    write_flat(fs, "/g/a.arw", tbl, row_group_rows=2048)
    ds = dataset(fs, "/g")
    q = (
        ds.query(format="pushdown")
        .filter(field("trip_id") < 100)
        .select("trip_id")
        .limit(10)
    )
    golden = """\
== logical plan ==
Limit[n=10]
  Project[trip_id]
    Filter[trip_id < 100]
      Scan[flat, fragments=2, rows=4096, columns=*]
== optimizer ==
- projection-pushdown: scan ships [trip_id]
- stats-pruning: 1 of 2 fragments pruned (0 by bloom index), 0 predicate-free after ALL verdicts
- limit-pushdown: row budget 10; plan truncated to 1 tasks (0 dropped), budget rides into scan_op
== physical plan ==
executor: streaming, format=pushdown, max_inflight=16, queue_depth=4/OSD, row_budget=10
fragments: 2 total, 1 pruned, 0 metadata-answered, 1 tasks
  [0] scan /g/a.arw#0 rows=2048 pred=trip_id < 100 limit<=10 | placement=osd
  [-] pruned /g/a.arw#0 (stats prove NONE)"""
    assert q.explain() == golden


def test_explain_shows_adaptive_placement(flat_ds):
    fs, ds, _ = flat_ds
    text = (
        ds.query(format="adaptive")
        .filter(field("fare_amount") > 25.0)
        .explain()
    )
    assert "placement=" in text and "est_osd=" in text
    assert "cached=no" in text


def test_explain_cache_probe_matches_executor_keys(flat_ds):
    """The explain() cache probe must mirror the keys the executor
    actually caches under — scans, aggregates, and the degenerate-count
    rowcount path alike."""
    from repro.core import AdaptiveFormat

    fs, ds, _ = flat_ds
    fmt = AdaptiveFormat()
    pred = field("fare_amount") > 25.0
    # count: cached under the rowcount sentinel key
    ds.query(format=fmt).filter(pred).count().to_scalar()
    text = ds.query(format=fmt).filter(pred).count().explain()
    assert "cached=yes" in text and "cached=no" not in text
    # grouped aggregate: cached under the agg spec key
    agg = ["count", ("mean", "fare_amount")]
    ds.query(format=fmt).aggregate(agg, group_by="passenger_count").to_table()
    text = (
        ds.query(format=fmt)
        .aggregate(agg, group_by="passenger_count")
        .explain()
    )
    assert "cached=yes" in text and "cached=no" not in text
    # scan: cached under the (columns, predicate, limit) key
    ds.query(format=fmt).filter(pred).select("trip_id").to_table()
    text = ds.query(format=fmt).filter(pred).select("trip_id").explain()
    assert "cached=yes" in text and "cached=no" not in text


# ---------------------------------------------------------------------------
# limit pushdown end-to-end: fragments past the budget are never scanned
# ---------------------------------------------------------------------------


def test_limit_plan_truncation_skips_fragments(flat_ds):
    fs, ds, tbl = flat_ds
    q = ds.query(format="pushdown").select("trip_id").limit(10)
    out = q.to_table()
    assert len(out) == 10
    # 20 fragments exist; the plan issued exactly one task
    assert q.metrics.fragments_total == 20
    assert len(q.metrics.tasks) == 1


@pytest.mark.parametrize("fmt", FORMATS)
def test_limit_early_exit_with_predicate(flat_ds, fmt):
    """A predicate the stats cannot prove forces runtime execution — the
    executor must stop issuing fragments once the row budget is met."""
    fs, ds, tbl = flat_ds
    pred = field("fare_amount") > 1.0  # ~everything matches, not provable
    q = (
        ds.query(format=fmt, num_threads=2)
        .filter(pred)
        .select("trip_id")
        .limit(50)
    )
    out = q.to_table()
    assert len(out) == 50
    mask = tbl.column("fare_amount").values > 1.0
    valid = set(tbl.column("trip_id").values[mask].tolist())
    assert set(out.column("trip_id").values.tolist()) <= valid
    # early exit: far fewer task records than fragments
    assert len(q.metrics.tasks) < q.metrics.fragments_total


def test_limit_rides_into_scan_op(flat_ds):
    """Storage nodes honour the budget: a limited pushdown scan ships at
    most `limit` rows per task (the node slices before IPC)."""
    fs, ds, tbl = flat_ds
    pred = field("fare_amount") > 1.0
    q = ds.query(format="pushdown").filter(pred).select("trip_id").limit(5)
    q.to_table()
    assert all(t.rows_out <= 5 for t in q.metrics.tasks)
    full = ds.query(format="pushdown").select("trip_id")
    full.to_table()
    limited_wire = max(t.wire_bytes for t in q.metrics.tasks)
    full_wire = max(t.wire_bytes for t in full.metrics.tasks)
    assert limited_wire < full_wire


def test_limit_streams_through_to_batches(flat_ds):
    fs, ds, _ = flat_ds
    q = ds.query(format="pushdown").select("trip_id").limit(1500)
    batches = list(q.to_batches())
    assert sum(len(b) for b in batches) == 1500


# ---------------------------------------------------------------------------
# wrapper equivalence: every Scanner verb == its query() lowering
# ---------------------------------------------------------------------------


def _sorted_ids(table):
    return np.sort(table.column("trip_id").values)


@pytest.mark.parametrize("fmt", FORMATS)
def test_wrapper_equivalence_to_table(populated, fmt):
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    pred = field("fare_amount") > 25.0
    sc = ds.scanner(format=fmt, columns=["trip_id"], predicate=pred)
    via_scanner = sc.to_table()
    via_query = (
        ds.query(format=fmt).filter(pred).select("trip_id").to_table()
    )
    assert via_scanner.schema.names == via_query.schema.names
    assert np.array_equal(_sorted_ids(via_scanner), _sorted_ids(via_query))
    mask = tbl.column("fare_amount").values > 25.0
    assert np.array_equal(
        _sorted_ids(via_scanner),
        np.sort(tbl.column("trip_id").values[mask]),
    )


@pytest.mark.parametrize("fmt", FORMATS)
def test_wrapper_equivalence_to_batches(populated, fmt):
    from repro.aformat.table import Table

    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    pred = field("fare_amount") > 30.0
    sc = ds.scanner(format=fmt, columns=["trip_id"], predicate=pred)
    streamed = Table.concat(list(sc.to_batches()))
    materialized = (
        ds.query(format=fmt).filter(pred).select("trip_id").to_table()
    )
    assert np.array_equal(_sorted_ids(streamed), _sorted_ids(materialized))


@pytest.mark.parametrize("fmt", FORMATS)
def test_wrapper_equivalence_aggregate(populated, fmt):
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    pred = field("fare_amount") > 25.0
    aggs = ["count", ("sum", "fare_amount"), ("mean", "fare_amount")]
    a = ds.scanner(format=fmt, predicate=pred).aggregate(
        aggs, group_by="passenger_count"
    )
    q = ds.query(format=fmt).filter(pred)
    b = q.aggregate(aggs, group_by="passenger_count").to_table()
    assert a.schema.names == b.schema.names
    assert np.array_equal(
        a.column("passenger_count").values,
        b.column("passenger_count").values,
    )
    for name in ("count", "sum_fare_amount", "mean_fare_amount"):
        assert np.allclose(
            a.column(name).values, b.column(name).values, rtol=1e-12
        )


@pytest.mark.parametrize("fmt", FORMATS)
def test_wrapper_equivalence_count(populated, fmt):
    fs, tbl, _ = populated
    ds = dataset(fs, "/d")
    pred = field("fare_amount") > 25.0
    exp = int((tbl.column("fare_amount").values > 25.0).sum())
    assert ds.scanner(format=fmt, predicate=pred).count_rows() == exp
    assert ds.query(format=fmt).filter(pred).count().to_scalar() == exp
    assert ds.query(format=fmt).count().to_scalar() == len(tbl)


# ---------------------------------------------------------------------------
# metrics: per-execution snapshots, uniform wall/fragment accounting
# ---------------------------------------------------------------------------


def test_scanner_metrics_do_not_accumulate_across_runs(flat_ds):
    """Regression: a second run on the same Scanner used to double-count
    rows / pruned fragments / tasks into one ScanMetrics."""
    fs, ds, tbl = flat_ds
    pred = field("trip_id") < 1024
    sc = ds.scanner(format="pushdown", predicate=pred)
    sc.to_table()
    first = sc.metrics
    n_tasks, n_pruned, n_rows = (
        len(first.tasks),
        first.fragments_pruned,
        first.rows,
    )
    sc.to_table()
    assert len(sc.metrics.tasks) == n_tasks
    assert sc.metrics.fragments_pruned == n_pruned
    assert sc.metrics.rows == n_rows
    # the first run's record is a frozen snapshot, not a shared object
    assert sc.metrics is not first


def test_aggregate_metrics_do_not_accumulate(flat_ds):
    fs, ds, _ = flat_ds
    sc = ds.scanner(format="pushdown")
    sc.aggregate(["count", ("min", "trip_id")])
    first_rows = sc.metrics.rows
    sc.aggregate(["count", ("min", "trip_id")])
    assert sc.metrics.rows == first_rows


@pytest.mark.parametrize("fmt", FORMATS)
def test_count_rows_records_wall_and_fragments(flat_ds, fmt):
    """Regression: static-pushdown count never set wall_s; the adaptive
    count never set fragments_total.  The unified executor records both
    for every verb."""
    fs, ds, tbl = flat_ds
    sc = ds.scanner(format=fmt, predicate=field("fare_amount") > 25.0)
    sc.count_rows()
    assert sc.metrics.fragments_total == len(ds.fragments())
    assert sc.metrics.wall_s > 0
    assert sc.metrics.admission != {}


def test_metadata_count_records_fragments_without_tasks(flat_ds):
    fs, ds, tbl = flat_ds
    sc = ds.scanner(format="pushdown")
    assert sc.count_rows() == len(tbl)
    assert not sc.metrics.tasks
    assert sc.metrics.fragments_total == len(ds.fragments())
    assert sc.metrics.metadata_answers == len(ds.fragments())
