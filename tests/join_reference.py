"""Pure-NumPy reference joins for the differential harness.

Independent implementation of the join semantics `Query.join` promises,
computed directly on in-memory Tables with sort + searchsorted (no hash
tables, no shared code with the executor), so agreement is meaningful:

- probe rows keep their input order; a probe row's matches surface in
  build-row order (stable sort preserves it within equal keys);
- null keys and NaN keys never match (SQL equality);
- semi joins emit probe columns only, keeping probe rows with >= 1 match;
- inner/left joins emit probe columns then build columns minus the build
  key; build names clashing with an used name get ``_right`` suffixed;
- a left join's unmatched probe rows null the build columns (validity
  False over zero/""-filled storage), and those columns' fields become
  nullable.
"""

from __future__ import annotations

import numpy as np

from repro.aformat.schema import Field, Schema
from repro.aformat.table import Column, Table


def _key_array(col: Column) -> tuple[np.ndarray, np.ndarray]:
    """(comparable key array, validity mask) with nulls/NaNs invalid."""
    vals = np.asarray(col.values)
    valid = (
        np.ones(len(vals), "?")
        if col.validity is None
        else col.validity.astype(bool)
    )
    if vals.dtype.kind == "f":
        valid = valid & ~np.isnan(vals)
    if vals.dtype.kind == "O":
        vals = np.asarray([str(v) for v in vals], object)
    return vals, valid


def _match_ranges(pk, pvalid, bk, bvalid):
    """For each probe row: (sorted-build lo, hi) half-open match range
    plus the build-row permutation that makes ranges contiguous.  A
    stable argsort keeps equal-key build rows in build-row order, which
    is exactly the executor's per-probe-row match order."""
    bidx = np.flatnonzero(bvalid)
    bkeys = bk[bidx]
    order = np.argsort(bkeys, kind="stable")
    skeys, srows = bkeys[order], bidx[order]
    lo = np.searchsorted(skeys, pk, side="left")
    hi = np.searchsorted(skeys, pk, side="right")
    lo = np.where(pvalid, lo, 0)
    hi = np.where(pvalid, hi, 0)
    return lo, hi, srows


def _null_column(field: Field, n: int) -> Column:
    vals = (
        np.array([""] * n, object)
        if field.type == "string"
        else np.zeros(n, field.numpy_dtype)
    )
    return Column(field, vals, np.zeros(n, "?"))


def output_fields(
    probe: Table, build: Table, on_left: str, on_right: str, how: str
) -> tuple[list[Field], list[tuple[str, Field]]]:
    """(joined output fields, [(build column, renamed output Field)])."""
    probe_fields = list(probe.schema)
    if how == "semi":
        return probe_fields, []
    used = {f.name for f in probe_fields}
    pairs: list[tuple[str, Field]] = []
    for f in build.schema:
        if f.name == on_right:
            continue
        out = f.name
        while out in used:
            out += "_right"
        used.add(out)
        pairs.append((f.name, Field(out, f.type,
                                    f.nullable or how == "left")))
    return probe_fields + [f for _, f in pairs], pairs


def reference_join(
    probe: Table,
    build: Table,
    *,
    on: "str | tuple[str, str]",
    how: str = "inner",
) -> Table:
    """Join two in-memory Tables the way ``Query.join`` promises to."""
    on_left, on_right = (on, on) if isinstance(on, str) else on
    pk, pvalid = _key_array(probe.column(on_left))
    bk, bvalid = _key_array(build.column(on_right))
    fields, pairs = output_fields(probe, build, on_left, on_right, how)

    if not bvalid.any():
        lo = hi = np.zeros(len(probe), np.int64)
        srows = np.empty(0, np.int64)
    else:
        lo, hi, srows = _match_ranges(pk, pvalid, bk, bvalid)
    counts = hi - lo

    if how == "semi":
        return probe.filter(counts > 0)

    if how == "inner":
        pi = np.repeat(np.arange(len(probe)), counts)
        total = int(counts.sum())
        # vectorized "concatenate(range(lo_i, hi_i))": offset each probe
        # row's slot index into its sorted-build range
        starts = np.repeat(lo, counts)
        offsets = np.arange(total) - np.repeat(
            np.cumsum(counts) - counts, counts
        )
        bi = srows[starts + offsets] if total else np.empty(0, np.int64)
    else:  # left
        out_counts = np.maximum(counts, 1)
        pi = np.repeat(np.arange(len(probe)), out_counts)
        total = int(out_counts.sum())
        starts = np.repeat(lo, out_counts)
        offsets = np.arange(total) - np.repeat(
            np.cumsum(out_counts) - out_counts, out_counts
        )
        slot = starts + offsets
        matched = np.repeat(counts > 0, out_counts)
        bi = np.where(
            matched,
            srows[np.where(matched, slot, 0)] if len(srows) else 0,
            -1,
        )

    cols = list(probe.take(pi).columns)
    for name, field in pairs:
        col = build.column(name)
        if len(col.values) == 0:
            cols.append(_null_column(field, len(pi)))
            continue
        ok = bi >= 0
        safe = np.where(ok, bi, 0)
        vals = col.values[safe]
        valid = (
            np.ones(len(bi), "?")
            if col.validity is None
            else col.validity[safe].astype(bool)
        )
        if not ok.all():
            vals = vals.copy()
            vals[~ok] = "" if field.type == "string" else 0
            valid = valid & ok
        cols.append(Column(field, vals, valid))
    return Table(Schema(tuple(fields)), cols)


def assert_tables_equal(actual: Table, expected: Table):
    """Byte-exact table equality: schema (names, types, nullability),
    row count, validity masks, and values — including the zero/""-fill
    convention under null slots, so storage is bit-identical too."""
    assert actual.schema == expected.schema, (
        f"schema mismatch:\n  actual   {actual.schema}\n"
        f"  expected {expected.schema}"
    )
    assert len(actual) == len(expected), (
        f"row count {len(actual)} != {len(expected)}"
    )
    for f, a, e in zip(actual.schema, actual.columns, expected.columns):
        va = np.ones(len(a), "?") if a.validity is None else a.validity
        ve = np.ones(len(e), "?") if e.validity is None else e.validity
        assert np.array_equal(va, ve), f"{f.name}: validity differs"
        if f.type == "string":
            assert [str(v) for v in a.values] == \
                [str(v) for v in e.values], f"{f.name}: values differ"
        else:
            assert a.values.dtype == e.values.dtype, (
                f"{f.name}: dtype {a.values.dtype} != {e.values.dtype}"
            )
            same = np.array_equal(
                a.values, e.values,
                equal_nan=a.values.dtype.kind == "f",
            )
            assert same, f"{f.name}: values differ"
