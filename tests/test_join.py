"""Differential + fault-injection harness for joins.

``Query.join`` promises *exactly reproducible* results: probe rows keep
scan order, a probe row's matches surface in build-row order, null/NaN
keys never match, and the storage-side semi-join pushdown (IN-list or
bloom filter conjoined into the probe ``scan_op``) must never change a
single output byte.  Every test here therefore asserts byte-exact
equality against ``tests/join_reference.py`` — an independent pure-NumPy
sort+searchsorted implementation that shares no code with the executor's
hash join.

Sections:
  * differential grid: layout x format x how x residual-predicate over
    data with null keys and duplicate keys on both sides;
  * builder validation + strategy selection (IN-list/bloom boundary,
    left-join and probe-limit opt-outs, selectivity-hint threading);
  * golden explain() rendering for join plans;
  * fault injection: probe-side OSD scan service down (clean client
    fallback, no partial rows), hedged build side (first reply wins
    exactly once), result-cache invalidation across append()/compact()
    and digest-keyed filters (no false hits);
  * a hypothesis property test (skipped when hypothesis is absent).
"""

import numpy as np
import pytest

from join_reference import assert_tables_equal, reference_join
from repro.aformat.expressions import BloomIn, IsIn, field
from repro.aformat.schema import schema
from repro.aformat.table import Column, Table
from repro.core import (
    dataset,
    make_cluster,
    write_flat,
    write_split,
    write_striped,
)
from repro.dataset import (
    AdaptiveFormat,
    MutableDataset,
    PushdownParquetFormat,
    ScanScheduler,
)
from repro.dataset.plan import IN_LIST_MAX
from repro.storage.objstore import OSDDownError

WRITERS = {
    "flat": write_flat,
    "striped": write_striped,
    "split": write_split,
}
FORMATS = ["parquet", "pushdown", "adaptive"]
HOWS = ["inner", "left", "semi"]


# ---------------------------------------------------------------------------
# fixtures: null keys + duplicate keys on BOTH sides, clashing column name
# ---------------------------------------------------------------------------


def _sample_tables():
    """(probe, build) with every awkward case the executor must handle:
    ~5% null probe keys, duplicate keys on both sides, a build column
    (``tag``) clashing with a probe column, null build keys.  Values
    under null slots are zeroed so the storage round-trip is
    bit-identical to the in-memory reference."""
    rng = np.random.default_rng(7)
    n = 3000
    kvalid = rng.random(n) > 0.05
    keys = np.where(kvalid, rng.integers(0, 60, n), 0).astype(np.int64)
    psch = schema(
        ("pid", "int64"), ("key", "int64"), ("amt", "float64"),
        ("tag", "string"), nullable=("key",),
    )
    probe = Table(psch, [
        Column(psch.field("pid"), np.arange(n, dtype=np.int64)),
        Column(psch.field("key"), keys, kvalid),
        Column(psch.field("amt"), np.round(rng.gamma(2.0, 7.5, n), 2)),
        Column(psch.field("tag"),
               rng.choice(np.array(["aa", "bb", "cc"], object), n)),
    ])
    m = 48
    bvalid = np.ones(m, "?")
    bvalid[[5, 40]] = False
    bkeys = np.where(bvalid, np.concatenate([
        np.arange(40, dtype=np.int64),
        np.array([3, 3, 7, 11, 55, 56, 57, 58], np.int64),
    ]), 0).astype(np.int64)
    bsch = schema(
        ("key", "int64"), ("weight", "float64"), ("tag", "string"),
        nullable=("key",),
    )
    build = Table(bsch, [
        Column(bsch.field("key"), bkeys, bvalid),
        Column(bsch.field("weight"), np.round(rng.normal(size=m), 3)),
        Column(bsch.field("tag"),
               rng.choice(np.array(["xx", "yy"], object), m)),
    ])
    return probe, build


@pytest.fixture(scope="module", params=["flat", "striped", "split"])
def join_store(request):
    probe, build = _sample_tables()
    fs = make_cluster(8)
    for i in range(3):
        WRITERS[request.param](
            fs, f"/probe/part{i}.arw", probe.slice(i * 1000, 1000),
            row_group_rows=256,
        )
    write_flat(fs, "/build/b0.arw", build, row_group_rows=16)
    return fs, probe, build


def _sorted_by(tbl: Table, name: str) -> Table:
    order = np.argsort(tbl.column(name).values, kind="stable")
    return tbl.take(order)


# ---------------------------------------------------------------------------
# the differential grid: layout x format x how x residual predicate
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("with_pred", [False, True], ids=["all", "pred"])
@pytest.mark.parametrize("how", HOWS)
@pytest.mark.parametrize("fmt", FORMATS)
def test_join_matches_reference(join_store, fmt, how, with_pred):
    """Byte-exact agreement with the NumPy reference across the full
    grid — same schema (incl. nullability), validity, values, order."""
    fs, probe, build = join_store
    q = dataset(fs, "/probe").query(format=fmt)
    ref_probe = probe
    if with_pred:
        q = q.filter(field("amt") > 12.0)
        ref_probe = probe.filter(probe.column("amt").values > 12.0)
    got = q.join(
        dataset(fs, "/build").query(), on="key", how=how
    ).to_table()
    expected = reference_join(ref_probe, build, on="key", how=how)
    assert_tables_equal(got, expected)


@pytest.mark.parametrize("how", HOWS)
def test_join_to_batches_streams_same_rows(join_store, how):
    """Streaming emits exactly the materialized rows (batches complete
    in any order, so compare after a stable sort on the probe id)."""
    fs, probe, build = join_store
    q = dataset(fs, "/probe").query(format="pushdown").join(
        dataset(fs, "/build").query(), on="key", how=how
    )
    batches = list(q.to_batches())
    got = (
        Table.concat(batches)
        if batches
        else reference_join(probe.head(0), build, on="key", how=how)
    )
    expected = reference_join(probe, build, on="key", how=how)
    assert_tables_equal(_sorted_by(got, "pid"), _sorted_by(expected, "pid"))


def test_post_join_filter_select_limit(join_store):
    """Verbs above the join (filter/select/limit) run on the joined
    output, deterministically."""
    fs, probe, build = join_store
    q = (
        dataset(fs, "/probe").query(format="pushdown")
        .join(dataset(fs, "/build").query(), on="key", how="inner")
        .filter(field("weight") > 0.0)
        .select("pid", "weight")
        .limit(40)
    )
    ref = reference_join(probe, build, on="key", how="inner")
    ref = ref.filter(ref.column("weight").values > 0.0)
    ref = ref.select(["pid", "weight"]).head(40)
    assert_tables_equal(q.to_table(), ref)


def test_join_build_side_projection_and_filter(join_store):
    """A filtered, projected build side: the key column is fetched even
    when not selected, and only selected columns join through."""
    fs, probe, build = join_store
    bq = (
        dataset(fs, "/build").query()
        .filter(field("weight") > 0.0)
        .select("weight")
    )
    got = dataset(fs, "/probe").query(format="pushdown").join(
        bq, on="key", how="inner"
    ).to_table()
    ref_build = build.filter(build.column("weight").values > 0.0)
    ref_build = ref_build.select(["key", "weight"])
    assert_tables_equal(
        got, reference_join(probe, ref_build, on="key", how="inner")
    )


def test_join_on_left_right_pair():
    """on=(left, right) with differently-named key columns; the build
    key column never appears in the output."""
    fs = make_cluster(4)
    psch = schema(("pid", "int64"), ("zone", "int64"))
    probe = Table(psch, [
        Column(psch.field("pid"), np.arange(50, dtype=np.int64)),
        Column(psch.field("zone"),
               (np.arange(50, dtype=np.int64) % 7)),
    ])
    bsch = schema(("zid", "int64"), ("name", "string"))
    build = Table(bsch, [
        Column(bsch.field("zid"), np.arange(5, dtype=np.int64)),
        Column(bsch.field("name"),
               np.array([f"z{i}" for i in range(5)], object)),
    ])
    write_flat(fs, "/p/p0.arw", probe, row_group_rows=32)
    write_flat(fs, "/b/b0.arw", build, row_group_rows=32)
    for how in HOWS:
        got = dataset(fs, "/p").query(format="pushdown").join(
            dataset(fs, "/b").query(), on=("zone", "zid"), how=how
        ).to_table()
        expected = reference_join(probe, build, on=("zone", "zid"), how=how)
        assert_tables_equal(got, expected)
        assert "zid" not in got.schema.names


def test_join_string_keys():
    fs = make_cluster(4)
    psch = schema(("pid", "int64"), ("tag", "string"))
    tags = np.array(["aa", "bb", "cc", "dd", "aa", "bb"] * 20, object)
    probe = Table(psch, [
        Column(psch.field("pid"), np.arange(len(tags), dtype=np.int64)),
        Column(psch.field("tag"), tags),
    ])
    bsch = schema(("tag", "string"), ("label", "string"))
    build = Table(bsch, [
        Column(bsch.field("tag"),
               np.array(["bb", "dd", "bb", "zz"], object)),
        Column(bsch.field("label"),
               np.array(["B1", "D", "B2", "Z"], object)),
    ])
    write_flat(fs, "/p/p0.arw", probe, row_group_rows=64)
    write_flat(fs, "/b/b0.arw", build, row_group_rows=64)
    for how in HOWS:
        got = dataset(fs, "/p").query(format="pushdown").join(
            dataset(fs, "/b").query(), on="tag", how=how
        ).to_table()
        assert_tables_equal(
            got, reference_join(probe, build, on="tag", how=how)
        )


def test_join_nan_float_keys_never_match():
    """NaN == NaN is false in SQL join semantics: NaN keys on either
    side match nothing (and survive only through a left join)."""
    fs = make_cluster(4)
    psch = schema(("pid", "int64"), ("k", "float64"))
    pk = np.array([1.0, np.nan, 2.0, np.nan, 3.0])
    probe = Table(psch, [
        Column(psch.field("pid"), np.arange(5, dtype=np.int64)),
        Column(psch.field("k"), pk),
    ])
    bsch = schema(("k", "float64"), ("v", "int64"))
    build = Table(bsch, [
        Column(bsch.field("k"), np.array([np.nan, 1.0, 3.0])),
        Column(bsch.field("v"), np.arange(3, dtype=np.int64)),
    ])
    write_flat(fs, "/p/p0.arw", probe, row_group_rows=8)
    write_flat(fs, "/b/b0.arw", build, row_group_rows=8)
    for how in HOWS:
        got = dataset(fs, "/p").query(format="pushdown").join(
            dataset(fs, "/b").query(), on="k", how=how
        ).to_table()
        assert_tables_equal(
            got, reference_join(probe, build, on="k", how=how)
        )
    semi = dataset(fs, "/p").query().join(
        dataset(fs, "/b").query(), on="k", how="semi"
    ).to_table()
    assert semi.column("pid").values.tolist() == [0, 4]


def test_join_mixed_int_widths():
    """int32 probe key against int64 build key joins exactly."""
    fs = make_cluster(4)
    psch = schema(("pid", "int64"), ("k", "int32"))
    probe = Table(psch, [
        Column(psch.field("pid"), np.arange(100, dtype=np.int64)),
        Column(psch.field("k"),
               (np.arange(100) % 9).astype(np.int32)),
    ])
    bsch = schema(("k", "int64"), ("v", "float64"))
    build = Table(bsch, [
        Column(bsch.field("k"), np.array([2, 5, 5, 11], np.int64)),
        Column(bsch.field("v"), np.array([0.5, 1.5, 2.5, 3.5])),
    ])
    write_flat(fs, "/p/p0.arw", probe, row_group_rows=32)
    write_flat(fs, "/b/b0.arw", build, row_group_rows=32)
    for how in HOWS:
        got = dataset(fs, "/p").query(format="pushdown").join(
            dataset(fs, "/b").query(), on="k", how=how
        ).to_table()
        assert_tables_equal(
            got, reference_join(probe, build, on="k", how=how)
        )


def test_join_empty_sides(join_store):
    """An all-filtered build side: inner/semi produce zero rows with the
    full joined schema; left keeps every probe row with all-null build
    columns.  An all-filtered probe side produces zero rows."""
    fs, probe, build = join_store
    empty_build = build.filter(np.zeros(len(build), "?"))
    for how in HOWS:
        got = dataset(fs, "/probe").query(format="pushdown").join(
            dataset(fs, "/build").query().filter(field("weight") > 1e9),
            on="key", how=how,
        ).to_table()
        assert_tables_equal(
            got, reference_join(probe, empty_build, on="key", how=how)
        )
    empty_probe = probe.filter(np.zeros(len(probe), "?"))
    for how in HOWS:
        got = dataset(fs, "/probe").query(format="pushdown").filter(
            field("amt") > 1e9
        ).join(dataset(fs, "/build").query(), on="key", how=how).to_table()
        assert_tables_equal(
            got, reference_join(empty_probe, build, on="key", how=how)
        )


def test_join_duplicate_keys_exact_order():
    """Pinned tiny case: probe rows keep scan order, and a probe row's
    matches come out in build-row order."""
    fs = make_cluster(4)
    psch = schema(("pid", "int64"), ("k", "int64"))
    probe = Table(psch, [
        Column(psch.field("pid"), np.arange(5, dtype=np.int64)),
        Column(psch.field("k"), np.array([7, 3, 3, 9, 7], np.int64)),
    ])
    bsch = schema(("k", "int64"), ("v", "int64"))
    build = Table(bsch, [
        Column(bsch.field("k"), np.array([3, 7, 3], np.int64)),
        Column(bsch.field("v"), np.array([10, 20, 30], np.int64)),
    ])
    write_flat(fs, "/p/p0.arw", probe, row_group_rows=8)
    write_flat(fs, "/b/b0.arw", build, row_group_rows=8)
    got = dataset(fs, "/p").query(format="pushdown").join(
        dataset(fs, "/b").query(), on="k", how="inner"
    ).to_table()
    assert got.column("pid").values.tolist() == [0, 1, 1, 2, 2, 4]
    assert got.column("v").values.tolist() == [20, 10, 30, 10, 30, 20]
    assert_tables_equal(
        got, reference_join(probe, build, on="k", how="inner")
    )


def test_probe_limit_join_is_subset():
    """A probe-side limit means "any n probe rows" — the joined output
    must still be a duplicate-free subset of the unlimited join."""
    probe, build = _sample_tables()
    fs = make_cluster(8)
    write_flat(fs, "/p/p0.arw", probe, row_group_rows=256)
    write_flat(fs, "/b/b0.arw", build, row_group_rows=64)
    q = dataset(fs, "/p").query(format="pushdown").limit(50).join(
        dataset(fs, "/b").query(), on="key", how="semi"
    )
    got = q.to_table()
    full = reference_join(probe, build, on="key", how="semi")
    pids = got.column("pid").values.tolist()
    assert len(pids) <= 50
    assert len(set(pids)) == len(pids)
    assert set(pids) <= set(full.column("pid").values.tolist())


# ---------------------------------------------------------------------------
# builder validation
# ---------------------------------------------------------------------------


def test_join_builder_validation(join_store):
    fs, _probe, _build = join_store
    q = dataset(fs, "/probe").query()
    b = dataset(fs, "/build").query()
    with pytest.raises(TypeError):
        q.join("not a query", on="key")
    with pytest.raises(ValueError, match="how must be one of"):
        q.join(b, on="key", how="outer")
    with pytest.raises(ValueError, match="nondeterministic subset"):
        q.join(b.limit(3), on="key")
    with pytest.raises(ValueError, match="aggregate"):
        q.join(b.aggregate(["count"]), on="key")
    with pytest.raises((KeyError, ValueError)):
        q.join(b, on="no_such_column")
    with pytest.raises(TypeError, match="join key types differ"):
        q.join(b, on=("key", "tag"))
    with pytest.raises(ValueError, match="left, right"):
        q.join(b, on=("a", "b", "c"))

    joined = q.join(b, on="key")
    with pytest.raises(ValueError, match="nested joins"):
        joined.join(b, on="key")
    with pytest.raises(ValueError, match="join is not supported"):
        joined.aggregate(["count"])
    with pytest.raises(ValueError, match="join is not supported"):
        joined.count()
    with pytest.raises(KeyError, match="not a join output column"):
        joined.select("no_such_column")
    # semi joins emit probe columns only: build columns are not
    # selectable
    semi = q.join(b, on="key", how="semi")
    with pytest.raises(KeyError):
        semi.select("weight")
    # the clash-renamed build column IS selectable on inner/left
    assert joined.select("pid", "tag_right") is not joined
    with pytest.raises(ValueError, match="join plans lower per side"):
        joined.physical_plan()


# ---------------------------------------------------------------------------
# pushdown strategy selection + selectivity hint
# ---------------------------------------------------------------------------


def _keyed_store(n_probe, build_sizes):
    fs = make_cluster(4)
    psch = schema(("pid", "int64"), ("k", "int64"))
    probe = Table(psch, [
        Column(psch.field("pid"), np.arange(n_probe, dtype=np.int64)),
        Column(psch.field("k"), np.arange(n_probe, dtype=np.int64)),
    ])
    write_flat(fs, "/p/p0.arw", probe, row_group_rows=256)
    bsch = schema(("k", "int64"),)
    for name, m in build_sizes.items():
        build = Table(
            bsch, [Column(bsch.field("k"), np.arange(m, dtype=np.int64))]
        )
        write_flat(fs, f"/{name}/b0.arw", build, row_group_rows=4096)
    return fs, probe


def test_strategy_inlist_bloom_boundary():
    """<= IN_LIST_MAX distinct keys push an exact IN-list; one more key
    switches to a bloom filter — and both stay byte-exact (bloom false
    positives die at the client's exact membership check)."""
    fs, probe = _keyed_store(
        600, {"small": IN_LIST_MAX, "big": IN_LIST_MAX + 1}
    )
    q_small = dataset(fs, "/p").query(format="pushdown").join(
        dataset(fs, "/small").query(), on="k", how="semi"
    )
    _plan, ctx, _bq, _post = q_small._prepare_join()
    s = ctx.strategy
    assert s.pushdown == "inlist"
    assert isinstance(s.key_filter, IsIn)
    assert s.distinct_keys == IN_LIST_MAX
    assert s.selectivity_hint == pytest.approx(IN_LIST_MAX / 600)

    q_big = dataset(fs, "/p").query(format="pushdown").join(
        dataset(fs, "/big").query(), on="k", how="semi"
    )
    _plan, ctx, _bq, _post = q_big._prepare_join()
    s = ctx.strategy
    assert s.pushdown == "bloom"
    assert isinstance(s.key_filter, BloomIn)
    assert s.key_filter.count == IN_LIST_MAX + 1
    # both run byte-exact
    bsch = schema(("k", "int64"),)
    for path, m in (("/small", IN_LIST_MAX), ("/big", IN_LIST_MAX + 1)):
        build = Table(
            bsch, [Column(bsch.field("k"), np.arange(m, dtype=np.int64))]
        )
        got = dataset(fs, "/p").query(format="pushdown").join(
            dataset(fs, path).query(), on="k", how="semi"
        ).to_table()
        assert_tables_equal(
            got, reference_join(probe, build, on="k", how="semi")
        )


def test_strategy_opt_outs():
    """Left joins and probe-side limits run the probe unfiltered."""
    fs, _probe = _keyed_store(100, {"b": 10})
    left = dataset(fs, "/p").query().join(
        dataset(fs, "/b").query(), on="k", how="left"
    )
    _plan, ctx, _bq, _post = left._prepare_join()
    assert ctx.strategy.pushdown == "none"
    assert ctx.strategy.reason == "left join keeps every probe row"
    assert ctx.strategy.key_filter is None

    limited = dataset(fs, "/p").query().limit(7).join(
        dataset(fs, "/b").query(), on="k", how="semi"
    )
    _plan, ctx, _bq, _post = limited._prepare_join()
    assert ctx.strategy.pushdown == "none"
    assert (
        ctx.strategy.reason
        == "probe-side limit pins pre-join row selection"
    )


def test_selectivity_hint_threads_to_tasks_and_pricing():
    """The hint rides every probe task and shrinks the scheduler's
    storage-side wire estimate (cheaper reply -> storage looks better),
    without entering the cache key."""
    fs, _probe = _keyed_store(1000, {"b": 10})
    q = dataset(fs, "/p").query(format="adaptive").join(
        dataset(fs, "/b").query(), on="k", how="semi"
    )
    plan, ctx, _bq, _post = q._prepare_join()
    hint = ctx.strategy.selectivity_hint
    assert hint == pytest.approx(10 / 1000)
    assert plan.tasks and all(
        t.selectivity_hint == hint for t in plan.tasks
    )

    sched = ScanScheduler(fs)
    sched._out_ratio.update(1.0)
    sched._decode_rate_osd.update(100e6)
    sched._decode_rate_client.update(100e6)
    frag = dataset(fs, "/p").fragments()[0]
    plain = sched.estimate(frag)
    hinted = sched.estimate(frag, selectivity_hint=0.01)
    assert hinted.est_osd_s < plain.est_osd_s


def test_pushdown_cuts_probe_wire_bytes():
    """The whole point: with a selective build side, the probe ships a
    fraction of the unfiltered scan's bytes, and the build-side scan is
    accounted separately so the comparison is honest."""
    probe, build = _sample_tables()
    fs = make_cluster(8)
    for i in range(3):
        write_striped(fs, f"/p/part{i}.arw", probe.slice(i * 1000, 1000),
                      row_group_rows=256)
    bsch = schema(("key", "int64"),)
    small = Table(
        bsch, [Column(bsch.field("key"), np.array([3, 11, 42], np.int64))]
    )
    write_flat(fs, "/b/b0.arw", small, row_group_rows=64)
    q = dataset(fs, "/p").query(format="pushdown").join(
        dataset(fs, "/b").query(), on="key", how="semi"
    )
    got = q.to_table()
    assert_tables_equal(
        got, reference_join(probe, small, on="key", how="semi")
    )
    assert q.metrics.build is not None
    assert q.metrics.build.rows == len(small)

    full = dataset(fs, "/p").query(format="pushdown")
    full.to_table()
    assert q.metrics.wire_bytes < 0.5 * full.metrics.wire_bytes


# ---------------------------------------------------------------------------
# explain(): golden join plans
# ---------------------------------------------------------------------------


def _golden_store():
    fs = make_cluster(4)
    sch = schema(("k", "int64"), ("v", "float64"))
    for i, lo in enumerate((0, 100)):
        t = Table(sch, [
            Column(sch.field("k"),
                   np.arange(lo, lo + 10, dtype=np.int64)),
            Column(sch.field("v"), np.linspace(0.0, 1.0, 10)),
        ])
        write_flat(fs, f"/g/part{i}.arw", t, row_group_rows=16)
    bsch = schema(("k", "int64"),)
    write_flat(
        fs, "/gb/b0.arw",
        Table(bsch,
              [Column(bsch.field("k"), np.array([2, 3, 5], np.int64))]),
        row_group_rows=16,
    )
    write_flat(
        fs, "/gbig/b0.arw",
        Table(bsch,
              [Column(bsch.field("k"), np.arange(300, dtype=np.int64))]),
        row_group_rows=512,
    )
    return fs


def test_explain_inlist_join_golden():
    fs = _golden_store()
    txt = dataset(fs, "/g").query(format="pushdown").join(
        dataset(fs, "/gb").query(), on="k", how="semi"
    ).explain()
    lines = txt.splitlines()
    assert any(line.strip() == "Join[semi, k = k]" for line in lines)
    assert "build:" in txt
    assert (
        "- strategy: hash semi join on k = k; build side 3 rows, "
        "3 distinct keys" in lines
    )
    assert (
        "- semijoin-pushdown: IN-list(3 keys) conjoined into probe scan "
        "(selectivity hint 0.1500)" in lines
    )
    # part1 (k in 100..109) is provably disjoint from the pushed
    # IN-list: pruned client-side from footer stats, never scanned
    assert any(
        line.startswith("  [-] pruned /g/part1.arw#0") for line in lines
    )
    task_lines = [ln for ln in lines if ln.lstrip().startswith("[0]")]
    assert task_lines and "/g/part0.arw" in task_lines[0]
    assert all("/g/part1.arw" not in ln for ln in task_lines)


def test_explain_bloom_join_golden():
    fs = _golden_store()
    txt = dataset(fs, "/g").query(format="pushdown").join(
        dataset(fs, "/gbig").query(), on="k", how="inner"
    ).explain()
    assert (
        "- strategy: hash inner join on k = k; build side 300 rows, "
        "300 distinct keys" in txt
    )
    assert "- semijoin-pushdown: bloom(" in txt
    assert "digest=" in txt
    assert "(selectivity hint 1.0000)" in txt


def test_explain_left_join_golden():
    fs = _golden_store()
    txt = dataset(fs, "/g").query(format="pushdown").join(
        dataset(fs, "/gb").query(), on="k", how="left"
    ).explain()
    assert (
        "- semijoin-pushdown: none (left join keeps every probe row)"
        in txt
    )
    txt = dataset(fs, "/g").query(format="pushdown").limit(5).join(
        dataset(fs, "/gb").query(), on="k", how="semi"
    ).explain()
    assert (
        "- semijoin-pushdown: none (probe-side limit pins pre-join row "
        "selection)" in txt
    )


# ---------------------------------------------------------------------------
# storage-side row-group skip for pushed key filters
# ---------------------------------------------------------------------------


def test_scan_op_stats_skip_row_groups(monkeypatch):
    """A pushed key filter lets ``scan_op`` skip decoding row groups
    whose footer stats prove zero matches — only the two groups holding
    the keys are touched out of eight."""
    from repro.aformat import parquet

    fs = make_cluster(4)
    sch = schema(("k", "int64"),)
    t = Table(
        sch, [Column(sch.field("k"), np.arange(1024, dtype=np.int64))]
    )
    write_flat(fs, "/skip/p0.arw", t, row_group_rows=128)
    name = fs.object_names("/skip/p0.arw")[0]

    decoded = []
    real = parquet.scan_row_group

    def counting(src, meta, rg, columns, predicate=None):
        decoded.append(rg)
        return real(src, meta, rg, columns, predicate)

    monkeypatch.setattr(parquet, "scan_row_group", counting)
    payload = {"predicate": IsIn("k", (5, 200)).to_json()}
    raw, _osd, _el = fs.store.cls_call(name, "scan_op", payload)
    out = Table.from_ipc(raw)
    assert sorted(out.column("k").values.tolist()) == [5, 200]
    assert len(decoded) == 2  # rgs [0,127] and [128,255]; six skipped


# ---------------------------------------------------------------------------
# fault injection
# ---------------------------------------------------------------------------


def _warm_to_storage(fmt: AdaptiveFormat, fs):
    """Teach the scheduler a selective history so placement goes to the
    storage node (mirrors test_scheduler's warm-up idiom)."""
    sched = fmt.scheduler_for(fs)
    sched._out_ratio.update(0.05)
    sched._decode_rate_osd.update(150e6)
    sched._decode_rate_client.update(150e6)
    return sched


def test_probe_osd_down_falls_back_cleanly():
    """The probe-side scan service dying mid-join must not surface
    partial rows: every storage-placed task falls back to a client read
    of the same fragment, and the result stays byte-exact."""
    probe, build = _sample_tables()
    fs = make_cluster(8)
    for i in range(3):
        write_striped(fs, f"/p/part{i}.arw", probe.slice(i * 1000, 1000),
                      row_group_rows=256)
    write_flat(fs, "/b/b0.arw", build, row_group_rows=64)

    def dying_scan(obj, payload):
        raise OSDDownError("scan service down")

    fs.store.register_cls("scan_op", dying_scan)
    fmt = AdaptiveFormat()
    _warm_to_storage(fmt, fs)
    q = dataset(fs, "/p").query(format=fmt).join(
        dataset(fs, "/b").query(format="parquet"), on="key", how="semi"
    )
    got = q.to_table()
    assert_tables_equal(
        got, reference_join(probe, build, on="key", how="semi")
    )
    stats = fmt.stats()
    # storage WAS attempted (the warmed estimate picked the OSD), and
    # every one of those attempts failed over to a client read
    assert stats["fallbacks"] > 0
    assert stats["fallbacks"] == stats["decisions"]["client"]
    assert stats["decisions"]["osd"] == 0


def test_hedged_build_side_first_reply_wins_once():
    """A pathological straggler on the build object's primary: hedging
    re-issues against a replica, the first reply wins, and the joined
    output is byte-exact — no duplicated or dropped build rows."""
    probe, build = _sample_tables()
    fs = make_cluster(8)
    write_flat(fs, "/p/p0.arw", probe.slice(0, 1000), row_group_rows=256)
    write_flat(fs, "/b/b0.arw", build, row_group_rows=16)
    name = fs.object_names("/b/b0.arw")[0]
    fs.store.primary_of(name).straggle_factor = 1e6

    q = dataset(fs, "/p").query(format="parquet").join(
        dataset(fs, "/b").query(
            format=PushdownParquetFormat(hedge_threshold_s=0.002)
        ),
        on="key", how="inner",
    )
    got = q.to_table()
    assert_tables_equal(
        got,
        reference_join(probe.slice(0, 1000), build, on="key", how="inner"),
    )
    assert q.metrics.build is not None
    assert q.metrics.build.hedged_tasks >= 1


def test_semi_join_cache_invalidated_by_version_bump():
    """Result-cache keys carry object versions and the pushed filter's
    digest: a warm repeat hits, append() exposes new rows immediately,
    and compact() (which rewrites objects) never serves stale entries."""
    probe, build = _sample_tables()
    fs = make_cluster(8)
    md = MutableDataset.create(fs, "/mut")
    md.append(probe.slice(0, 1000), row_group_rows=256)
    write_flat(fs, "/b/b0.arw", build, row_group_rows=64)
    fmt = AdaptiveFormat()
    _warm_to_storage(fmt, fs)

    def run(expect_probe, sort=False):
        q = md.as_of().query(format=fmt).join(
            dataset(fs, "/b").query(format="parquet"),
            on="key", how="semi",
        )
        got = q.to_table()
        expected = reference_join(expect_probe, build, on="key",
                                  how="semi")
        if sort:
            got, expected = _sorted_by(got, "pid"), _sorted_by(expected,
                                                               "pid")
        assert_tables_equal(got, expected)

    run(probe.slice(0, 1000))
    h0 = fmt.stats()["cache"]["hits"]
    run(probe.slice(0, 1000))
    assert fmt.stats()["cache"]["hits"] > h0  # warm repeat hit

    md.append(probe.slice(1000, 1000), row_group_rows=256)
    run(probe.slice(0, 2000))  # new snapshot: fresh rows, exact

    md.compact(target_rows=4096)
    # rewritten objects -> new (name, version) keys; compaction may
    # reorder rows across objects, so compare order-independently
    run(probe.slice(0, 2000), sort=True)


def test_cache_keys_distinguish_pushed_key_filters():
    """Two different build sides push different (digest-keyed) filters:
    the second join must not be served from the first one's cache."""
    fs, probe = _keyed_store(600, {})
    bsch = schema(("k", "int64"),)
    evens = Table(
        bsch,
        [Column(bsch.field("k"),
                np.arange(0, 600, 2, dtype=np.int64))],
    )
    odds = Table(
        bsch,
        [Column(bsch.field("k"),
                np.arange(1, 600, 2, dtype=np.int64))],
    )
    write_flat(fs, "/be/b0.arw", evens, row_group_rows=1024)
    write_flat(fs, "/bo/b0.arw", odds, row_group_rows=1024)
    fmt = AdaptiveFormat()
    _warm_to_storage(fmt, fs)
    for path, build in (("/be", evens), ("/bo", odds), ("/be", evens)):
        got = dataset(fs, "/p").query(format=fmt).join(
            dataset(fs, path).query(format="parquet"),
            on="k", how="semi",
        ).to_table()
        assert_tables_equal(
            got, reference_join(probe, build, on="k", how="semi")
        )


# ---------------------------------------------------------------------------
# property-based differential test (skips when hypothesis is absent)
# ---------------------------------------------------------------------------


def test_join_property_random_tables():
    pytest.importorskip("hypothesis")
    from hypothesis import HealthCheck, given, settings
    from hypothesis import strategies as st

    key = st.one_of(st.none(), st.integers(0, 12))

    def make(keys, prefix):
        sch = schema((f"{prefix}id", "int64"), ("k", "int64"),
                     nullable=("k",))
        valid = np.array([k is not None for k in keys], "?")
        vals = np.array([k if k is not None else 0 for k in keys],
                        np.int64)
        return Table(sch, [
            Column(sch.field(f"{prefix}id"),
                   np.arange(len(keys), dtype=np.int64)),
            Column(sch.field("k"), vals, valid),
        ])

    @settings(max_examples=20, deadline=None,
              suppress_health_check=list(HealthCheck))
    @given(
        pkeys=st.lists(key, min_size=1, max_size=40),
        bkeys=st.lists(key, min_size=1, max_size=30),
        how=st.sampled_from(HOWS),
    )
    def check(pkeys, bkeys, how):
        fs = make_cluster(4)
        probe, build = make(pkeys, "p"), make(bkeys, "b")
        write_flat(fs, "/p/p0.arw", probe, row_group_rows=16)
        write_flat(fs, "/b/b0.arw", build, row_group_rows=16)
        got = dataset(fs, "/p").query(format="pushdown").join(
            dataset(fs, "/b").query(), on="k", how=how
        ).to_table()
        assert_tables_equal(
            got, reference_join(probe, build, on="k", how=how)
        )

    check()
