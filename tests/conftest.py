import numpy as np
import pytest

from repro.aformat.table import Table
from repro.core import make_cluster


@pytest.fixture
def fs():
    return make_cluster(8)


@pytest.fixture
def taxi_table():
    """NYC-taxi-like table (the paper's workload shape)."""
    rng = np.random.default_rng(42)
    n = 20_000
    return Table.from_pydict({
        "trip_id": np.arange(n, dtype=np.int64),
        "passenger_count": rng.integers(1, 7, n).astype(np.int32),
        "trip_distance": rng.gamma(1.5, 2.0, n).astype(np.float32),
        "fare_amount": rng.gamma(2.0, 7.5, n).astype(np.float64),
        "payment_type": rng.choice(["card", "cash", "disp"], n),
    })
