"""§Perf knob paths (tuned_hints, rs_epilogue) must be semantics-preserving:
same loss/gradients as the baseline path, only placement/precision of the
TP epilogue boundary changes (bf16 reduce-scatter, documented)."""

import pytest
import subprocess
import sys

# slow lane: jax/pallas compile-heavy; skipped by `make test-fast` / CI per-push
pytestmark = pytest.mark.slow
import textwrap

CODE = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses
    import jax, jax.numpy as jnp, numpy as np
    from repro.configs import smoke_config
    from repro.sharding import default_rules
    from repro.train import optim, step as step_mod

    base = smoke_config("starcoder2-7b")
    base = dataclasses.replace(
        base, num_layers=2, d_model=64, d_ff=128, num_heads=8,
        num_kv_heads=4, head_dim=16, vocab_size=128, remat=False)
    mesh = jax.make_mesh((2, 4), ("data", "model"))
    rules = default_rules()
    opt = optim.OptConfig(warmup_steps=0)
    key = jax.random.key(0)
    toks = jax.random.randint(key, (8, 33), 0, 128, jnp.int32)
    batch = {"tokens": toks[:, :-1], "labels": toks[:, 1:]}

    losses = {}
    grads0 = {}
    for name, kw in [("base", {}),
                     ("tuned", {"tuned_hints": True}),
                     ("rs", {"rs_epilogue": True}),
                     ("both", {"tuned_hints": True, "rs_epilogue": True})]:
        cfg = dataclasses.replace(base, **kw)
        state, _ = step_mod.init_state(cfg, opt, key)
        fn = jax.jit(step_mod.make_train_step(cfg, mesh, rules, opt))
        new_state, mets = fn(state, batch)
        losses[name] = float(mets["loss"])
        grads0[name] = np.asarray(
            jax.tree.leaves(new_state["params"])[0]).ravel()[:8]

    print("losses:", {k: round(v, 5) for k, v in losses.items()})
    for name in ("tuned", "rs", "both"):
        assert abs(losses[name] - losses["base"]) < 2e-3, (name, losses)
        np.testing.assert_allclose(grads0[name], grads0["base"],
                                   rtol=5e-2, atol=5e-3)
    print("PERF_KNOBS_OK")
""")


def test_perf_knobs_preserve_semantics():
    out = subprocess.run([sys.executable, "-c", CODE], capture_output=True,
                         text=True,
                         env={"PYTHONPATH": "src", "PATH": "/usr/bin:/bin"},
                         cwd="/root/repo")
    assert "PERF_KNOBS_OK" in out.stdout, (out.stdout[-1500:],
                                           out.stderr[-3000:])
