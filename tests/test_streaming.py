"""The concurrent streaming execution engine and per-OSD admission.

``Scanner.to_batches`` must stream with *bounded in-flight fragments*
driven by consumption (backpressure), ``to_table`` must be a faithful
materialization of the same stream, and the unified admission controller
must gate every placement's per-OSD concurrency — the properties the
millions-of-users ingest path rests on.
"""

import threading
import time

import numpy as np
import pytest

from repro.aformat.expressions import field
from repro.aformat.table import Table
from repro.core import ParquetFormat, dataset, make_cluster, write_flat
from repro.dataset.admission import AdmissionController


@pytest.fixture
def flat_ds(taxi_table):
    fs = make_cluster(8)
    for i in range(4):
        write_flat(fs, f"/d/part{i}.arw", taxi_table.slice(i * 5000, 5000),
                   row_group_rows=1024)
    return fs, dataset(fs, "/d"), taxi_table


class _CountingFormat(ParquetFormat):
    """Client-side format instrumented with concurrent-scan accounting."""

    def __init__(self, delay_s: float = 0.0):
        super().__init__()
        self.delay_s = delay_s
        self.started = 0
        self.inflight = 0
        self.peak = 0
        self._lock = threading.Lock()

    def scan_fragment(self, fs, frag, columns, predicate, ctx=None):
        with self._lock:
            self.started += 1
            self.inflight += 1
            self.peak = max(self.peak, self.inflight)
        try:
            if self.delay_s:
                time.sleep(self.delay_s)
            return super().scan_fragment(fs, frag, columns, predicate, ctx)
        finally:
            with self._lock:
                self.inflight -= 1


# ---------------------------------------------------------------------------
# to_batches: streaming semantics
# ---------------------------------------------------------------------------


def test_to_batches_bounds_inflight(flat_ds):
    fs, ds, _ = flat_ds
    fmt = _CountingFormat(delay_s=0.002)
    sc = ds.scanner(format=fmt, columns=["trip_id"], num_threads=16)
    batches = list(sc.to_batches(max_inflight=3))
    assert fmt.peak <= 3
    assert fmt.started == len(ds.fragments())
    assert sum(len(b) for b in batches) == ds.num_rows


def test_to_batches_backpressure(flat_ds):
    """A paused consumer pauses the producer: after pulling one batch, at
    most max_inflight + 1 fragments have ever been issued (the window plus
    the one refill triggered by the consumed batch)."""
    fs, ds, _ = flat_ds
    fmt = _CountingFormat()
    sc = ds.scanner(format=fmt, columns=["trip_id"], num_threads=16)
    it = sc.to_batches(max_inflight=2)
    next(it)
    started_after_one = fmt.started
    assert started_after_one <= 3       # 2 in window + 1 refill
    it.close()                          # abandoning the stream is clean
    assert fmt.started <= started_after_one + 2


def test_to_batches_matches_to_table(flat_ds):
    fs, ds, tbl = flat_ds
    pred = field("fare_amount") > 30.0
    streamed = Table.concat(list(
        ds.scanner(format="pushdown", columns=["trip_id"], predicate=pred,
                   num_threads=4).to_batches()))
    materialized = ds.scanner(format="pushdown", columns=["trip_id"],
                              predicate=pred, num_threads=4).to_table()
    assert np.array_equal(np.sort(streamed.column("trip_id").values),
                          np.sort(materialized.column("trip_id").values))


def test_to_batches_skips_empty_fragments(flat_ds):
    fs, ds, tbl = flat_ds
    # trip_id < 100 matches only the very first row group
    batches = list(ds.scanner(format="pushdown", columns=["trip_id"],
                              predicate=field("trip_id") < 100,
                              num_threads=4).to_batches())
    assert all(len(b) for b in batches)
    assert sum(len(b) for b in batches) == 100


def test_to_table_preserves_plan_order(flat_ds):
    """to_table rides the completion-ordered stream but must reassemble
    fragments in plan order (clients relied on it pre-streaming)."""
    fs, ds, tbl = flat_ds
    out = ds.scanner(format="parquet", columns=["trip_id"],
                     num_threads=8).to_table()
    vals = out.column("trip_id").values
    assert np.array_equal(vals, np.sort(vals))


# ---------------------------------------------------------------------------
# unified admission control
# ---------------------------------------------------------------------------


def test_admission_controller_bounds_per_osd():
    fs = make_cluster(4)
    ctrl = AdmissionController(fs.store, slots_per_osd=2)
    peak = {"v": 0}
    cur = {"v": 0}
    lock = threading.Lock()

    def worker():
        with ctrl.admit(0):
            with lock:
                cur["v"] += 1
                peak["v"] = max(peak["v"], cur["v"])
            time.sleep(0.005)
            with lock:
                cur["v"] -= 1

    threads = [threading.Thread(target=worker) for _ in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert peak["v"] <= 2
    assert ctrl.admitted == 8
    assert ctrl.waits > 0


@pytest.mark.parametrize("fmt", ["parquet", "pushdown", "adaptive"])
def test_all_formats_honour_admission(flat_ds, fmt):
    """Every placement draws from the same per-OSD slots: with one slot
    per node and a wide thread pool, the scan still completes and the
    controller reports real contention."""
    fs, ds, tbl = flat_ds
    sc = ds.scanner(format=fmt, columns=["trip_id"], num_threads=16,
                    queue_depth=1)
    out = sc.to_table()
    assert len(out) == len(tbl)
    assert sc.metrics.admission["admitted"] == len(sc.metrics.tasks)
    assert sc.metrics.admission["slots_per_osd"] == 1


def test_adaptive_cache_hits_skip_admission(flat_ds):
    from repro.core import AdaptiveFormat
    fs, ds, _ = flat_ds
    fmt = AdaptiveFormat()
    ds.scanner(format=fmt, columns=["trip_id"], num_threads=4).to_table()
    sc = ds.scanner(format=fmt, columns=["trip_id"], num_threads=4)
    sc.to_table()
    assert sc.metrics.cache_hits == len(sc.metrics.tasks)
    assert sc.metrics.admission["admitted"] == 0   # never touched a node


# ---------------------------------------------------------------------------
# streaming ingest (serving path)
# ---------------------------------------------------------------------------


def test_ingest_prompts_streams_and_groups():
    from repro.serve.engine import ingest_prompts
    fs = make_cluster(4)
    rng = np.random.default_rng(11)
    uids = np.repeat(np.arange(24, dtype=np.int64), 16)
    pos = np.tile(np.arange(16, dtype=np.int32), 24)
    toks = rng.integers(0, 5000, uids.size).astype(np.int32)
    # shuffle rows so uid groups straddle fragment boundaries
    perm = rng.permutation(uids.size)
    tbl = Table.from_pydict({"uid": uids[perm], "pos": pos[perm],
                             "token": toks[perm]})
    write_flat(fs, "/prompts/p0.arw", tbl, row_group_rows=64)
    ds = dataset(fs, "/prompts")
    reqs, metrics = ingest_prompts(ds, format="pushdown")
    assert len(reqs) == 24
    for r in reqs:
        sel = uids == r.uid
        expect = toks[sel][np.argsort(pos[sel], kind="stable")]
        assert np.array_equal(r.prompt, expect)
