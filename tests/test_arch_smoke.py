"""Per-architecture smoke tests: reduced same-family config, one real
forward + train step on CPU, asserting output shapes and finiteness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

# slow lane: jax/pallas compile-heavy; skipped by `make test-fast` / CI per-push
pytestmark = pytest.mark.slow

from repro.configs import ARCHS, smoke_config
from repro.launch.mesh import make_local_mesh
from repro.models import api as model_api
from repro.sharding import ShardingCtx, default_rules
from repro.train import optim, step as step_mod

BATCH, SEQ = 2, 32


def _smoke_batch(cfg, key):
    b = {
        "tokens": jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab_size,
                                     jnp.int32),
        "labels": jax.random.randint(key, (BATCH, SEQ), 0, cfg.vocab_size,
                                     jnp.int32),
    }
    if cfg.family == "vlm":
        b["patches"] = jnp.ones((BATCH, cfg.num_image_tokens, cfg.d_model),
                                jnp.float32) * 0.02
    if cfg.family == "encdec":
        b["frames"] = jnp.ones((BATCH, cfg.encoder_seq, cfg.d_model),
                               jnp.float32) * 0.02
    return b


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_train_step_smoke(arch):
    cfg = smoke_config(arch)
    cfg = dataclasses.replace(cfg, num_layers=min(cfg.num_layers, 2),
                              remat=False)
    mesh = make_local_mesh(1, 1)
    rules = default_rules()
    opt = optim.OptConfig()
    key = jax.random.key(0)
    state, _ = step_mod.init_state(cfg, opt, key)
    fn = jax.jit(step_mod.make_train_step(cfg, mesh, rules, opt))
    batch = _smoke_batch(cfg, key)
    new_state, metrics = fn(state, batch)
    loss = float(metrics["loss"])
    assert np.isfinite(loss)
    assert loss > 0
    assert int(new_state["step"]) == 1
    # params actually moved
    d0 = jax.tree.leaves(state["params"])[0]
    d1 = jax.tree.leaves(new_state["params"])[0]
    assert not np.allclose(np.asarray(d0), np.asarray(d1))
    # loss ~ log(vocab) at init (random labels): sanity on scale
    assert loss < 2 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", sorted(ARCHS))
def test_prefill_decode_smoke(arch):
    cfg = smoke_config(arch)
    cfg = dataclasses.replace(cfg, num_layers=min(cfg.num_layers, 2),
                              remat=False)
    mesh = make_local_mesh(1, 1)
    rules = default_rules()
    ctx = ShardingCtx(mesh, rules)
    key = jax.random.key(1)
    from repro.models import lm
    params, _ = lm.init_params(cfg, key)
    batch = _smoke_batch(cfg, key)
    batch.pop("labels")
    logits, cache = model_api.prefill(cfg, ctx, params, batch)
    assert logits.shape == (BATCH, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits)).all()
    nxt = jnp.argmax(logits, -1)[:, None].astype(jnp.int32)
    logits2, cache = model_api.decode_step(cfg, ctx, params, cache, nxt,
                                           jnp.asarray(SEQ, jnp.int32))
    assert logits2.shape == (BATCH, cfg.vocab_size)
    assert np.isfinite(np.asarray(logits2)).all()


def test_decode_matches_prefill_next_token():
    """Teacher-forced decode must reproduce prefill logits (causality)."""
    cfg = smoke_config("starcoder2-7b")
    cfg = dataclasses.replace(cfg, num_layers=2, remat=False)
    mesh = make_local_mesh(1, 1)
    ctx = ShardingCtx(mesh, default_rules())
    from repro.models import lm
    key = jax.random.key(2)
    params, _ = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 16), 0, cfg.vocab_size, jnp.int32)

    # full prefill over 16 tokens
    full_logits, _ = model_api.prefill(cfg, ctx, params, {"tokens": toks})
    # prefill over 15, pad headroom, then decode token 15
    pre_logits, cache = model_api.prefill(cfg, ctx, params,
                                          {"tokens": toks[:, :15]})
    cache = model_api.pad_cache(cache, 4)
    dec_logits, _ = model_api.decode_step(cfg, ctx, params, cache,
                                          toks[:, 15:16],
                                          jnp.asarray(15, jnp.int32))
    np.testing.assert_allclose(np.asarray(dec_logits),
                               np.asarray(full_logits), rtol=2e-2,
                               atol=2e-2)


def test_ssm_decode_matches_prefill_next_token():
    """Causality check for the attention-free (Mamba2/SSD) family: prefill
    a chunk-divisible prefix, teacher-force decode the rest, and compare
    against a single full prefill (the dual chunked form vs the pure
    recurrence)."""
    cfg = smoke_config("mamba2-780m")
    cfg = dataclasses.replace(cfg, num_layers=2, remat=False, ssm_chunk=8)
    mesh = make_local_mesh(1, 1)
    ctx = ShardingCtx(mesh, default_rules())
    from repro.models import lm
    key = jax.random.key(4)
    params, _ = lm.init_params(cfg, key)
    toks = jax.random.randint(key, (1, 24), 0, cfg.vocab_size, jnp.int32)
    full_logits, _ = model_api.prefill(cfg, ctx, params, {"tokens": toks})
    logits, cache = model_api.prefill(cfg, ctx, params,
                                      {"tokens": toks[:, :16]})
    for j in range(16, 24):
        logits, cache = model_api.decode_step(cfg, ctx, params, cache,
                                              toks[:, j:j + 1],
                                              jnp.asarray(j, jnp.int32))
    np.testing.assert_allclose(np.asarray(logits),
                               np.asarray(full_logits), rtol=5e-2,
                               atol=5e-2)
